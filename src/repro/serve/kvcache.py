"""Tiered paged KV cache: AION's m-bucket/p-bucket applied to serving.

Long-lived decode sessions are exactly "window state that must outlive the
memory horizon": each session's KV is block-granular **pages**; hot pages
live in the device pool (m-bucket) read by the ``decode_attention_paged``
kernel via the block table; cold pages are offloaded to a host pool
(p-bucket). The three paper mechanisms map one-to-one:

* proactive caching   — sessions predicted to decode soon (inter-arrival
                        EWMA per session) get their pages staged ahead of
                        the predicted time; staging > late-writes >
                        destaging priority via the same IOScheduler.
* predictive cleanup  — the distribution of session inter-arrival gaps
                        yields an adaptive idle bound (coverage quantile
                        with a DKW band); sessions idle past it are evicted
                        entirely.
* staleness trigger   — (engine-side) governs re-scoring of session
                        aggregates; not needed per token.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cleanup import PredictiveCleanup


@dataclass
class Session:
    session_id: int
    length: int = 0                       # valid tokens
    pages: List[int] = field(default_factory=list)      # device page ids
    host_pages: Dict[int, Tuple[np.ndarray, np.ndarray]] = \
        field(default_factory=dict)       # logical page -> (k, v) host copies
    last_arrival: float = 0.0
    gap_ewma: float = 1.0
    finished: bool = False

    def predicted_next(self) -> float:
        return self.last_arrival + self.gap_ewma


class TieredKVCache:
    """Page pool: device tier (fixed pages) + host tier (unbounded)."""

    def __init__(self, *, num_device_pages: int, page_size: int,
                 num_kv_heads: int, head_dim: int, num_layers: int,
                 dtype=jnp.bfloat16, cleanup: Optional[PredictiveCleanup] = None):
        self.page_size = page_size
        self.num_device_pages = num_device_pages
        self.shape = (num_layers, num_device_pages, page_size,
                      num_kv_heads, head_dim)
        self.k_pool = jnp.zeros(self.shape, dtype)
        self.v_pool = jnp.zeros(self.shape, dtype)
        self.free_pages: List[int] = list(range(num_device_pages))
        self.sessions: Dict[int, Session] = {}
        # page ownership: device page -> (session, logical page idx)
        self.owner: Dict[int, Tuple[int, int]] = {}
        self.cleanup = cleanup or PredictiveCleanup(
            coverage=0.95, confidence=0.9, initial_bound=600.0,
            min_history=50)
        self.stats = {"staged": 0, "destaged": 0, "evicted_sessions": 0,
                      "alloc_fail": 0}

    # ------------------------------------------------------------ sessions
    def open_session(self, session_id: int, now: float) -> Session:
        s = Session(session_id=session_id, last_arrival=now)
        self.sessions[session_id] = s
        return s

    def observe_arrival(self, session_id: int, now: float) -> None:
        s = self.sessions[session_id]
        gap = max(now - s.last_arrival, 1e-6)
        if s.length:
            s.gap_ewma = 0.7 * s.gap_ewma + 0.3 * gap
            self.cleanup.observe(np.asarray([gap]))
        s.last_arrival = now

    # --------------------------------------------------------------- pages
    def _alloc_page(self, now: float) -> Optional[int]:
        if self.free_pages:
            return self.free_pages.pop()
        victim = self._pick_victim(now)
        if victim is None:
            self.stats["alloc_fail"] += 1
            return None
        self._destage_page(*victim)
        return self.free_pages.pop()

    def _pick_victim(self, now: float) -> Optional[Tuple[int, int]]:
        """Evict from the session with the largest predicted time until
        next decode (proactive: keep imminent sessions resident)."""
        best, best_score = None, -np.inf
        for sid, s in self.sessions.items():
            if not s.pages or s.finished:
                continue
            score = s.predicted_next() - now
            if s.finished:
                score = np.inf
            if score > best_score:
                # prefer the session's oldest page (front of the context)
                for li, pg in enumerate(s.pages):
                    if pg >= 0:
                        best, best_score = (sid, li), score
                        break
        return best

    def _destage_page(self, session_id: int, logical_idx: int) -> None:
        s = self.sessions[session_id]
        pg = s.pages[logical_idx]
        k = np.asarray(self.k_pool[:, pg])
        v = np.asarray(self.v_pool[:, pg])
        s.host_pages[logical_idx] = (k, v)
        s.pages[logical_idx] = -1
        self.owner.pop(pg, None)
        self.free_pages.append(pg)
        self.stats["destaged"] += 1

    def _stage_page(self, session_id: int, logical_idx: int,
                    now: float) -> bool:
        s = self.sessions[session_id]
        if s.pages[logical_idx] >= 0:
            return True
        pg = self._alloc_page(now)
        if pg is None:
            return False
        k, v = s.host_pages.pop(logical_idx)
        self.k_pool = self.k_pool.at[:, pg].set(jnp.asarray(k))
        self.v_pool = self.v_pool.at[:, pg].set(jnp.asarray(v))
        s.pages[logical_idx] = pg
        self.owner[pg] = (session_id, logical_idx)
        self.stats["staged"] += 1
        return True

    # ------------------------------------------------------------- appends
    def append_token_kv(self, session_id: int, k_token: np.ndarray,
                        v_token: np.ndarray, now: float) -> bool:
        """k/v_token: [num_layers, num_kv_heads, head_dim]."""
        s = self.sessions[session_id]
        slot = s.length % self.page_size
        logical = s.length // self.page_size
        if logical >= len(s.pages):
            pg = self._alloc_page(now)
            if pg is None:
                return False
            s.pages.append(pg)
            self.owner[pg] = (session_id, logical)
        elif s.pages[logical] < 0:
            if not self._stage_page(session_id, logical, now):
                return False
        pg = s.pages[logical]
        self.k_pool = self.k_pool.at[:, pg, slot].set(jnp.asarray(k_token))
        self.v_pool = self.v_pool.at[:, pg, slot].set(jnp.asarray(v_token))
        s.length += 1
        return True

    # ----------------------------------------------------------- proactive
    def prestage_due(self, now: float, horizon: float = 0.5) -> int:
        """Stage pages of sessions predicted to decode within ``horizon``
        seconds (proactive caching). Returns pages staged."""
        staged = 0
        order = sorted(self.sessions.values(),
                       key=lambda s: s.predicted_next())
        for s in order:
            if s.finished or s.predicted_next() - now > horizon:
                continue
            for li in list(s.host_pages.keys()):
                if self._stage_page(s.session_id, li, now):
                    staged += 1
        return staged

    # ------------------------------------------------------------- cleanup
    def cleanup_idle(self, now: float) -> int:
        """Predictive cleanup: evict sessions idle past the adaptive bound."""
        bound = self.cleanup.current_bound()
        evicted = 0
        for sid in list(self.sessions):
            s = self.sessions[sid]
            if s.finished or now - s.last_arrival > bound:
                for li, pg in enumerate(s.pages):
                    if pg >= 0:
                        self.owner.pop(pg, None)
                        self.free_pages.append(pg)
                s.pages.clear()
                s.host_pages.clear()
                del self.sessions[sid]
                evicted += 1
        self.stats["evicted_sessions"] += evicted
        return evicted

    # -------------------------------------------------------------- lookup
    def block_table(self, session_ids: List[int], pages_per_seq: int
                    ) -> Tuple[jnp.ndarray, jnp.ndarray, List[int]]:
        """(block_table [B, pages_per_seq], seq_lens [B], missing_pages).
        Missing pages (host-resident) are reported so the caller can stage
        them before launching the kernel (staging has max priority)."""
        table = np.full((len(session_ids), pages_per_seq), -1, np.int32)
        lens = np.zeros((len(session_ids),), np.int32)
        missing = []
        for i, sid in enumerate(session_ids):
            s = self.sessions[sid]
            lens[i] = s.length
            for li, pg in enumerate(s.pages[:pages_per_seq]):
                if pg < 0:
                    missing.append((sid, li))
                else:
                    table[i, li] = pg
        return jnp.asarray(table), jnp.asarray(lens), missing

    def device_pages_used(self) -> int:
        return self.num_device_pages - len(self.free_pages)
