"""Serving driver: batched prefill + decode with the model-level cache.

    PYTHONPATH=src python -m repro.launch.serve --arch mamba2-780m \
        --smoke --batch 4 --prompt-len 64 --new-tokens 32

Runs greedy decoding for a batch of synthetic prompts and reports
tokens/sec. The tiered paged-KV serving path (AION m/p-buckets + the
Pallas paged-attention kernel) is exercised by examples/serve_lm.py and
tests/test_fault_serve.py; this driver is the plain model-level loop the
dry-run's ``serve_step`` lowers.
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.models import build_model
from repro.serve import make_decode_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-780m")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=32)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduced(cfg)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)),
        jnp.int32)}
    if cfg.family in ("audio", "encdec"):
        batch["frame_embeds"] = jnp.asarray(
            rng.normal(size=(args.batch, cfg.frontend_tokens, cfg.d_model))
            * 0.02, jnp.bfloat16)
    if cfg.family == "vlm":
        batch["patch_embeds"] = jnp.asarray(
            rng.normal(size=(args.batch, cfg.frontend_tokens, cfg.d_model))
            * 0.02, jnp.bfloat16)

    max_len = args.prompt_len + args.new_tokens + cfg.frontend_tokens
    prefill = jax.jit(lambda p, b: model.prefill(p, b, max_len=max_len))
    decode = jax.jit(make_decode_step(model))

    t0 = time.time()
    logits, cache = prefill(params, batch)
    next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    prefill_s = time.time() - t0

    generated = [np.asarray(next_tok)]
    t1 = time.time()
    for _ in range(args.new_tokens - 1):
        next_tok, cache = decode(params, next_tok, cache)
        generated.append(np.asarray(next_tok))
    decode_s = time.time() - t1

    total_new = args.batch * args.new_tokens
    print(f"[serve] {cfg.name}: prefill {args.batch}x{args.prompt_len} in "
          f"{prefill_s:.2f}s; decoded {total_new} tokens in {decode_s:.2f}s "
          f"({total_new / max(decode_s, 1e-9):.1f} tok/s)")
    sample = np.concatenate(generated, axis=1)[0][:16]
    print(f"[serve] sample continuation ids: {sample.tolist()}")


if __name__ == "__main__":
    main()
