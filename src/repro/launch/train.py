"""Training driver: config-driven, fault-tolerant, restartable.

    PYTHONPATH=src python -m repro.launch.train --arch starcoder2-7b \
        --smoke --steps 50 --ckpt-dir /tmp/ckpt

``--smoke`` trains the reduced same-family config on CPU (the full configs
are for real pods; their distribution plan is proven by the dry-run).
Checkpoints are async + atomic; a SIGKILL mid-run resumes from LATEST.
"""
import argparse
import time
from pathlib import Path

import jax
import numpy as np

from repro.configs import ARCHS, get_config, reduced
from repro.data.generators import token_batches
from repro.data.pipeline import PrefetchPipeline
from repro.distributed.fault import RestartManager
from repro.models import build_model
from repro.train import OptConfig, make_train_step
from repro.train.checkpoint import (
    AsyncCheckpointer, latest_checkpoint, read_manifest, restore_checkpoint,
)
from repro.train.train_step import init_train_state


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="starcoder2-7b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", type=Path, default=Path("/tmp/repro_ckpt"))
    ap.add_argument("--save-every", type=int, default=25)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = reduced(cfg)
    model = build_model(cfg)
    opt_cfg = OptConfig(lr=args.lr, warmup_steps=max(args.steps // 20, 1),
                        total_steps=args.steps)
    step_fn = jax.jit(make_train_step(model, opt_cfg))

    data = PrefetchPipeline(
        token_batches(cfg.vocab_size, args.batch, args.seq), depth=2)
    ckpt = AsyncCheckpointer(args.ckpt_dir, keep=3)
    state_shapes = jax.eval_shape(
        lambda: init_train_state(model, jax.random.PRNGKey(0)))

    def restore():
        latest = latest_checkpoint(args.ckpt_dir)
        if latest is None:
            return None
        manifest = read_manifest(latest)
        state = restore_checkpoint(latest, state_shapes)
        print(f"[train] restored step {manifest['step']} from {latest}")
        return state, manifest["step"]

    t0 = time.time()

    def one_step(state, step):
        batch = next(data)
        state, metrics = step_fn(state, batch)
        if (step + 1) % args.log_every == 0 or step == 0:
            print(f"step {step + 1:5d} loss={float(metrics['loss']):.4f} "
                  f"gnorm={float(metrics['grad_norm']):.3f} "
                  f"lr={float(metrics['lr']):.2e} "
                  f"({(time.time() - t0) / (step + 1):.2f}s/step)")
        return state

    rm = RestartManager(save_every=args.save_every)
    final = rm.run(
        init_state=lambda: init_train_state(model, jax.random.PRNGKey(0)),
        restore=restore,
        step_fn=one_step,
        save=lambda s, step: ckpt.save(s, step),
        num_steps=args.steps,
    )
    ckpt.wait()
    data.close()
    print(f"[train] done: {args.steps} steps of {cfg.name} "
          f"({cfg.param_count() / 1e6:.1f}M params) in "
          f"{time.time() - t0:.1f}s; last checkpoint step "
          f"{ckpt.last_saved_step}")


if __name__ == "__main__":
    main()
