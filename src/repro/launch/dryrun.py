import os
_N_DEV = os.environ.get("REPRO_DRYRUN_DEVICES", "512")
os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={_N_DEV}"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this proves the distribution config is coherent without real
hardware: ``jax.jit(step).lower(**ShapeDtypeStructs).compile()`` must
succeed on the 16x16 single-pod mesh and the 2x16x16 multi-pod mesh, and we
record ``memory_analysis()`` / ``cost_analysis()`` / per-collective bytes
(parsed from the post-SPMD optimized HLO) for the roofline analysis.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun                    # all cells
    PYTHONPATH=src python -m repro.launch.dryrun --arch mamba2-780m \
        --shape train_4k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --mesh multi --skip-existing
"""
import argparse
import collections
import json
import re
import time
import traceback
from pathlib import Path
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs import (
    ARCHS, SHAPES_BY_NAME, applicable_shapes, get_config, skipped_cells,
)
from repro.configs.base import MeshConfig, ModelConfig, ShapeConfig
from repro.distributed import sharding as shd
from repro.launch.mesh import make_mesh_from_config, mesh_config
from repro.models import build_model
from repro.models.model import input_specs
from repro.serve import make_decode_step, make_prefill_step
from repro.train import make_train_step, make_train_state_specs
from repro.train.train_step import (
    TrainState, choose_microbatches, choose_remat_group, init_train_state,
)

DEFAULT_OUT = Path("experiments/dryrun")
_VARIANT: Dict[str, Any] = {}

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

# one HLO op definition line: "%name = TYPE[shape]{layout} opcode(...)"
_COLLECTIVE_RE = re.compile(
    r"=\s*(?P<out>\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*)\s+"
    r"(?P<op>all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(?:-start)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_stats(hlo: str) -> Dict[str, Dict[str, float]]:
    """Sum output-shape bytes per collective op kind over the optimized HLO.

    Output bytes are per-participating-device tensor sizes in the SPMD
    module (HLO shapes are already per-device after partitioning)."""
    stats: Dict[str, Dict[str, float]] = collections.defaultdict(
        lambda: {"count": 0, "bytes": 0})
    for m in _COLLECTIVE_RE.finditer(hlo):
        op = m.group("op")
        stats[op]["count"] += 1
        stats[op]["bytes"] += _shape_bytes(m.group("out"))
    return dict(stats)


def _tree_bytes_per_device(sds_tree, sharding_tree) -> int:
    """Exact per-device bytes from shard shapes."""
    total = 0
    for sds, sh in zip(jax.tree.leaves(sds_tree), jax.tree.leaves(
            sharding_tree, is_leaf=lambda x: x is None or hasattr(x, "shard_shape"))):
        if sh is None or not hasattr(sh, "shard_shape"):
            total += sds.size * sds.dtype.itemsize
        else:
            shp = sh.shard_shape(sds.shape)
            n = 1
            for d in shp:
                n *= d
            total += n * sds.dtype.itemsize
    return total


def _shardings_from_logical(mesh, logical_tree, rules):
    def leaf(spec):
        return jax.sharding.NamedSharding(
            mesh, shd.logical_to_pspec(spec, rules))
    return jax.tree.map(leaf, logical_tree,
                        is_leaf=lambda x: isinstance(x, tuple))


def lower_cell(cfg: ModelConfig, shape: ShapeConfig, mesh_cfg: MeshConfig,
               mesh, variant: Optional[Dict[str, Any]] = None
               ) -> Dict[str, Any]:
    """Lower + compile one cell, return the dry-run record.

    ``variant``: §Perf knobs — {causal_skip, kv_bits, compress_grads,
    remat, mu} override the baseline program for hillclimb measurements."""
    variant = variant or {}
    rec: Dict[str, Any] = {
        "arch": cfg.name, "shape": shape.name, "kind": shape.kind,
        "mesh": {"shape": list(mesh_cfg.shape), "axes": list(mesh_cfg.axes)},
        "chips": mesh_cfg.num_devices, "variant": variant,
    }
    profile = shd.sharding_profile(cfg, mesh_cfg, shape.global_batch,
                                   shape.seq_len, shape.kind)
    rules = shd.make_rules(cfg, mesh_cfg, shape.global_batch,
                           shape.seq_len, shape.kind)
    rec["profile"] = {
        "attn_tp": profile.attn_tp, "mlp_tp": profile.mlp_tp,
        "vocab_tp": profile.vocab_tp, "expert_tp": profile.expert_tp,
        "ssd_tp": profile.ssd_tp, "kv_repeat": profile.kv_repeat,
        "kv_seq_shard": profile.kv_seq_shard,
        "batch_axes": list(profile.batch_axes), "notes": list(profile.notes),
    }
    remat_group = 0
    if shape.kind == "train":
        mu_probe = choose_microbatches(cfg, shape, mesh_cfg, profile)
        remat_group = variant.get("remat_group") or choose_remat_group(
            cfg, shape, mesh_cfg, profile, mu_probe)
    import dataclasses as _dc
    if "remat" in variant:
        cfg = _dc.replace(cfg, remat=variant["remat"])
    if "param_dtype" in variant:
        cfg = _dc.replace(cfg, param_dtype=variant["param_dtype"])
    model = build_model(cfg, kv_repeat=profile.kv_repeat,
                        remat_group=remat_group,
                        causal_skip=variant.get("causal_skip", False),
                        kv_cache_bits=variant.get("kv_bits", 16),
                        kv_dus_write=variant.get("kv_dus", False))
    rec["profile"]["remat_group"] = remat_group
    ctx = shd.ShardCtx(mesh=mesh, rules=rules, profile=profile)

    batch_sds, batch_logical = input_specs(cfg, shape, model)
    with shd.use_ctx(ctx):
        batch_sh = _shardings_from_logical(mesh, batch_logical, rules)
        t0 = time.time()
        if shape.kind == "train":
            mu = variant.get("mu") or choose_microbatches(
                cfg, shape, mesh_cfg, profile)
            rec["profile"]["num_microbatches"] = mu
            grad_transform = None
            if variant.get("compress_grads"):
                from repro.train.compression import _int8_roundtrip
                import jax as _jax
                grad_transform = lambda g: _jax.tree.map(_int8_roundtrip, g)
            step = make_train_step(model, num_microbatches=mu,
                                   grad_transform=grad_transform)
            state_sds = jax.eval_shape(
                lambda: init_train_state(model, jax.random.PRNGKey(0)))
            state_logical = make_train_state_specs(model)
            state_sh = _shardings_from_logical(mesh, state_logical, rules)
            jitted = jax.jit(step, in_shardings=(state_sh, batch_sh),
                             out_shardings=(state_sh, None),
                             donate_argnums=(0,))
            lowered = jitted.lower(state_sds, batch_sds)
            rec["state_bytes_per_device"] = _tree_bytes_per_device(
                state_sds, state_sh)
        elif shape.kind == "prefill":
            pstep = make_prefill_step(model, max_len=shape.seq_len)
            params_sds = jax.eval_shape(
                lambda: model.init(jax.random.PRNGKey(0)))
            params_sh = _shardings_from_logical(mesh, model.specs(), rules)
            jitted = jax.jit(pstep, in_shardings=(params_sh, batch_sh))
            lowered = jitted.lower(params_sds, batch_sds)
            rec["state_bytes_per_device"] = _tree_bytes_per_device(
                params_sds, params_sh)
        else:  # decode
            dstep = make_decode_step(model)
            params_sds = jax.eval_shape(
                lambda: model.init(jax.random.PRNGKey(0)))
            params_sh = _shardings_from_logical(mesh, model.specs(), rules)
            cache_sds = batch_sds["cache"]
            cache_sh = batch_sh["cache"]
            tok_sds = batch_sds["tokens"]
            tok_sh = batch_sh["tokens"]
            jitted = jax.jit(dstep,
                             in_shardings=(params_sh, tok_sh, cache_sh),
                             out_shardings=(tok_sh, cache_sh),
                             donate_argnums=(2,))
            lowered = jitted.lower(params_sds, tok_sds, cache_sds)
            rec["state_bytes_per_device"] = _tree_bytes_per_device(
                params_sds, params_sh)
            rec["cache_bytes_per_device"] = _tree_bytes_per_device(
                cache_sds, cache_sh)
        rec["lower_s"] = round(time.time() - t0, 2)

        t0 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t0, 2)

    try:
        mem = compiled.memory_analysis()
        rec["memory_analysis"] = {
            k: int(getattr(mem, k))
            for k in ("argument_size_in_bytes", "output_size_in_bytes",
                      "temp_size_in_bytes", "generated_code_size_in_bytes",
                      "alias_size_in_bytes")
            if hasattr(mem, k)
        }
    except Exception as e:  # pragma: no cover - backend dependent
        rec["memory_analysis"] = {"error": str(e)}
    try:
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0]
        rec["cost_analysis"] = {
            k: float(v) for k, v in cost.items()
            if isinstance(v, (int, float)) and (
                k in ("flops", "bytes accessed", "optimal_seconds")
                or k.startswith("bytes accessed"))
        }
    except Exception as e:  # pragma: no cover
        rec["cost_analysis"] = {"error": str(e)}

    hlo = compiled.as_text()
    rec["collectives"] = collective_stats(hlo)
    rec["hlo_bytes"] = len(hlo)
    del hlo
    rec["model_params"] = cfg.param_count()
    rec["model_active_params"] = cfg.active_param_count()
    return rec


def run(archs, shapes, meshes, out_dir: Path, skip_existing: bool = False
        ) -> Tuple[int, int]:
    out_dir.mkdir(parents=True, exist_ok=True)
    ok = failed = 0
    from repro.configs.base import MeshConfig as _MC
    extra = {
        "quad": _MC((4, 16, 16), ("pod", "data", "model")),
        "degraded": _MC((8, 16), ("data", "model")),
    }
    for mesh_name in meshes:
        if mesh_name in extra:
            mcfg = extra[mesh_name]
        else:
            mcfg = mesh_config(multi_pod=(mesh_name == "multi"))
        mesh = make_mesh_from_config(mcfg)
        for arch in archs:
            cfg = get_config(arch)
            valid = {s.name for s in applicable_shapes(cfg)}
            for shape_name in shapes:
                if shape_name not in valid:
                    continue
                shape = SHAPES_BY_NAME[shape_name]
                tag = f"{mesh_name}__{arch}__{shape_name}"
                path = out_dir / f"{tag}.json"
                if skip_existing and path.exists():
                    existing = json.loads(path.read_text())
                    if "error" not in existing:
                        print(f"[skip] {tag}")
                        ok += 1
                        continue
                print(f"[dryrun] {tag} ...", flush=True)
                t0 = time.time()
                try:
                    rec = lower_cell(cfg, shape, mcfg, mesh,
                                     variant=_VARIANT)
                    rec["total_s"] = round(time.time() - t0, 2)
                    path.write_text(json.dumps(rec, indent=2))
                    ma = rec.get("memory_analysis", {})
                    print(f"  ok in {rec['total_s']}s  "
                          f"flops={rec['cost_analysis'].get('flops', 0):.3e} "
                          f"temp={ma.get('temp_size_in_bytes', 0)/2**30:.2f}GiB "
                          f"colls={ {k: v['count'] for k, v in rec['collectives'].items()} }",
                          flush=True)
                    ok += 1
                except Exception as e:
                    failed += 1
                    err = {"arch": arch, "shape": shape_name,
                           "mesh": mesh_name, "error": str(e),
                           "traceback": traceback.format_exc()}
                    path.write_text(json.dumps(err, indent=2))
                    print(f"  FAILED: {e}", flush=True)
    # record assigned-but-skipped cells for the report
    (out_dir / "skipped.json").write_text(
        json.dumps([{"arch": a, "shape": s, "reason": r}
                    for a, s, r in skipped_cells()], indent=2))
    return ok, failed


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", action="append", default=None,
                    help="arch id (repeatable; default all)")
    ap.add_argument("--shape", action="append", default=None,
                    help="shape name (repeatable; default all)")
    ap.add_argument("--mesh", choices=["single", "multi", "both",
                                       "quad", "degraded"],
                    default="both",
                    help="quad: 4x16x16=1024 chips (needs "
                         "REPRO_DRYRUN_DEVICES=1024); degraded: 8x16 "
                         "(half-pod elastic-restart mesh)")
    ap.add_argument("--out", type=Path, default=DEFAULT_OUT)
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--causal-skip", action="store_true")
    ap.add_argument("--kv-bits", type=int, default=16)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--remat", choices=["full", "dots"], default=None)
    ap.add_argument("--mu", type=int, default=None)
    ap.add_argument("--param-dtype", default=None,
                    help="serve in bf16: --param-dtype bfloat16")
    ap.add_argument("--remat-group", type=int, default=None)
    ap.add_argument("--kv-dus", action="store_true",
                    help="per-shard DUS cache write (SPerf C3)")
    args = ap.parse_args()
    variant = {}
    if args.causal_skip:
        variant["causal_skip"] = True
    if args.kv_bits != 16:
        variant["kv_bits"] = args.kv_bits
    if args.compress_grads:
        variant["compress_grads"] = True
    if args.remat:
        variant["remat"] = args.remat
    if args.mu:
        variant["mu"] = args.mu
    if args.param_dtype:
        variant["param_dtype"] = args.param_dtype
    if args.remat_group:
        variant["remat_group"] = args.remat_group
    if args.kv_dus:
        variant["kv_dus"] = True
    global _VARIANT
    _VARIANT = variant

    need = {"quad": 1024, "degraded": 128}.get(args.mesh, 512)
    assert len(jax.devices()) >= need, (
        f"dry-run requires >= {need} placeholder devices; set "
        f"REPRO_DRYRUN_DEVICES and re-run (XLA_FLAGS is read before "
        f"jax import)")
    archs = args.arch or sorted(ARCHS)
    shapes = args.shape or list(SHAPES_BY_NAME)
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    ok, failed = run(archs, shapes, meshes, args.out,
                     skip_existing=args.skip_existing)
    print(f"\ndry-run complete: {ok} ok, {failed} failed")
    if failed:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
