"""Learned prefetch subsystem (ROADMAP item 3).

Replaces the paper's fixed-margin proactive-caching scheme
(``core.proactive.PrestageScheduler``) with a learned, cost-aware
readahead planner, selected by ``AionConfig.prefetch_backend``:

* ``model``   — online lateness model: per key-class empirical-CDF fits
  (the same ``core.staleness.empirical_cdf`` machinery predictive
  cleanup uses) predict each window's re-execution probability, plus an
  online staging-cost/bandwidth estimate that keeps the
  ``StagingCostModel`` interface the engine observes through.
* ``planner`` — segment-granular readahead: maps predicted
  re-executions to the *log segments* holding their records
  (``LogBlockStore.segments_for``) and schedules sequential segment
  sweeps against a bandwidth-vs-deadline-slack cost model, picking
  coalescing candidates (scattered windows worth rewriting into one
  contiguous run) along the way.
* ``scheduler`` — ``LearnedPrestageScheduler``: the drop-in
  ``PrestageScheduler``-shaped front the engine talks to.

The fixed-margin path stays the default (``prefetch_backend="fixed"``)
and the differential-testing baseline.
"""
from repro.prefetch.model import LatenessModel, LearnedCostModel
from repro.prefetch.planner import PlanResult, SegmentPrefetchPlanner, SegmentSweep
from repro.prefetch.scheduler import LearnedPrestageScheduler

__all__ = [
    "LatenessModel", "LearnedCostModel",
    "SegmentPrefetchPlanner", "SegmentSweep", "PlanResult",
    "LearnedPrestageScheduler",
]
