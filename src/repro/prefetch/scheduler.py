"""``LearnedPrestageScheduler`` — the drop-in ``PrestageScheduler``
front for the learned prefetch backend.

The engine keeps talking to the same five-method surface (plan /
on_late_event / due / drive_readahead / cancel); underneath, the
deadline bookkeeping is still the fixed scheduler's heap (timing is a
solved problem there), while *what* gets read ahead, *how*, and
*whether* it is worth it becomes model-driven:

* ``observe_late`` feeds per-key lateness samples into the
  ``LatenessModel`` (``core.staleness`` empirical-CDF fits per
  key-class).
* ``drive_readahead`` replaces the per-window point readahead with the
  ``SegmentPrefetchPlanner``: candidate windows are gated by predicted
  re-execution probability, mapped to log segments, merged into
  sequential sweeps priced against the learned store bandwidth, and —
  for hot scattered windows — queued for coalescing rewrites.
* ``readahead_now`` is the pipelined hook (``engine.prefetch_round``):
  sweep whatever the busy device round will need, ahead of the stage
  requests, at the same transfer priority so the sweeps actually run
  first.
"""
from __future__ import annotations

import math
from typing import List, Optional, Set, Tuple

import numpy as np

from repro.core.buckets import Tier, WindowState
from repro.core.proactive import PrestageScheduler
from repro.core.windows import WindowId
from repro.prefetch.model import LatenessModel, LearnedCostModel
from repro.prefetch.planner import SegmentPrefetchPlanner


def _storage_keys(state: WindowState) -> List[Tuple[Tuple[float, float], int]]:
    return [(b.window_key, b.block_id) for b in state.blocks
            if b.tier == Tier.STORAGE and not b.dropped and b.in_storage
            and b.window_key is not None]


class LearnedPrestageScheduler:
    """Lateness-model-driven, segment-granular prefetch scheduler."""

    segment_granular = True

    def __init__(self, aion, *, punctuated: bool = False,
                 margin: float = 0.0):
        self.aion = aion
        self.margin = margin
        self.cost = LearnedCostModel(
            prior_bandwidth_bytes_per_s=aion.prefetch_bandwidth_bytes_per_s)
        self.model = LatenessModel(num_classes=aion.prefetch_key_classes)
        self._base = PrestageScheduler(self.cost, punctuated=punctuated)
        budget = aion.prefetch_budget_bytes or aion.store_readahead_bytes
        self.planner = SegmentPrefetchPlanner(
            self.cost, budget_bytes=budget,
            coalesce=aion.prefetch_coalesce,
            coalesce_probability=aion.prefetch_coalesce_probability)
        # windows hinted by upcoming() whose sweeps were deferred (over
        # budget / too much slack) — carried to the next drive
        self._pending: Set[WindowId] = set()
        self.stats_extra = {"windows_considered": 0,
                            "windows_skipped_low_probability": 0,
                            "point_fallbacks": 0}

    # ------------------------------------------------- PrestageScheduler API
    @property
    def punctuated(self) -> bool:
        return self._base.punctuated

    @property
    def stats(self) -> dict:
        out = dict(self._base.stats)
        out.update(self.planner.stats)
        out.update(self.stats_extra)
        return out

    def plan(self, window: WindowId, state: WindowState, exec_time: float,
             now: float, min_margin: float = 0.0) -> None:
        self._base.plan(window, state, exec_time, now, min_margin)

    def on_late_event(self, window: WindowId, state: WindowState,
                      now: float) -> None:
        self._base.on_late_event(window, state, now)

    def observe_late(self, window: WindowId, keys: np.ndarray,
                     delays: np.ndarray) -> None:
        self.model.observe(window, keys, delays)

    def planned_stage_at(self, window: WindowId) -> Optional[float]:
        return self._base.planned_stage_at(window)

    def due(self, now: float) -> List[WindowId]:
        out = self._base.due(now)
        for wid in out:
            self._pending.discard(wid)
        return out

    def upcoming(self, now: float, horizon: float) -> List[WindowId]:
        return self._base.upcoming(now, horizon)

    def cancel(self, window: WindowId) -> None:
        self._base.cancel(window)
        self._pending.discard(window)
        self.model.forget(window)
        self.planner.forget(window)

    # ------------------------------------------------------------ readahead
    def drive_readahead(self, engine, now: float, horizon: float) -> None:
        io = engine.io
        if io.store is None:
            return
        eff_horizon = self.aion.prefetch_horizon or 4.0 * horizon
        self._pending.update(self._base.upcoming(now, eff_horizon))
        if not self._pending:
            return

        wm = engine.tracker.watermark
        wants = []
        for wid in list(self._pending):
            stage_at = self._base.planned_stage_at(wid)
            state = engine.windows.get(wid)
            if stage_at is None or state is None:
                self._pending.discard(wid)
                continue
            keys = _storage_keys(state)
            if not keys:
                self._pending.discard(wid)
                continue
            self.stats_extra["windows_considered"] += 1
            age = max(wm - wid.end, 0.0) if math.isfinite(wm) else 0.0
            p = self.model.reexec_probability(wid, age)
            if p < self.aion.prefetch_min_probability:
                # model says this window's keys went quiet: not worth
                # cache space now — re-evaluated on the next drive
                self.stats_extra["windows_skipped_low_probability"] += 1
                continue
            wants.append((wid, stage_at, keys, p))
        if not wants:
            return

        if not hasattr(io.store, "segments_for") \
                or not hasattr(io, "request_segment_readahead"):
            # npz-style store: no segment index — point readahead
            for wid, _sa, _k, _p in wants:
                state = engine.windows.get(wid)
                if state is not None:
                    io.request_readahead(state)
                    self.stats_extra["point_fallbacks"] += 1
                self._pending.discard(wid)
            return

        result = self.planner.plan(io.store, wants, now)
        for sweep in result.sweeps:
            io.request_segment_readahead(sweep.sid, sweep.keys,
                                         on_swept=self.cost.observe_bytes)
        # satisfied windows leave the pending set; deferred sweeps (over
        # budget / ample slack) keep theirs queued for the next drive
        self._pending -= {wid for wid, _sa, _k, _p in wants}
        self._pending |= result.deferred_windows
        if result.coalesce and hasattr(io, "request_coalesce"):
            io.request_coalesce(
                [(wid.start, wid.end) for wid in result.coalesce])

    def readahead_now(self, io, states: List[WindowState]) -> int:
        """Pipelined hook: sweep the segments holding ``states``'s
        storage blocks immediately (same priority class as the stage
        requests that follow, so FIFO order runs the sweeps first).
        Returns the number of sweeps issued."""
        if io.store is None or not hasattr(io.store, "segments_for") \
                or not hasattr(io, "request_segment_readahead"):
            return 0
        from repro.core.staging import PRIO_STAGE
        all_keys = []
        for state in states:
            all_keys.extend(_storage_keys(state))
        if not all_keys:
            return 0
        placement = io.store.segments_for(all_keys)
        for sid, items in placement.items():
            io.request_segment_readahead(
                sid, [k for k, _, _ in items],
                on_swept=self.cost.observe_bytes, priority=PRIO_STAGE)
        return len(placement)
