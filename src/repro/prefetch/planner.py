"""Segment-granular readahead planning against a bandwidth/slack cost
model.

The fixed scheme issues one point readahead per upcoming window — on the
log store that is a per-record seek/read sweep whose records may be
scattered over many segments. The planner instead:

1. maps every prefetch-worthy window's storage-resident blocks to the
   log segments holding their live records (``store.segments_for`` —
   the index query, no payload reads),
2. merges records across windows into per-segment **sweeps** (one
   contiguous byte-range read per segment), and
3. schedules sweeps earliest-deadline-first against a cost model:
   a sweep is issued when its estimated read time
   (``span_bytes / bandwidth``, from ``LearnedCostModel``) no longer
   comfortably fits in the slack before its earliest staging deadline —
   prefetching at the *latest responsible moment* keeps the bounded
   read cache from churning on data whose deadline is far out — capped
   by a per-round byte budget (defaulting to the cache budget itself:
   issuing more than the cache holds just evicts our own prefetches).

It also nominates **coalescing** candidates: windows likely to
re-execute whose records are scattered (multiple segments, or a sparse
span within one segment) get rewritten into one contiguous run
(``store.coalesce_windows``), so the *next* re-stage is a single dense
sequential read. Selectivity is what keeps write amplification bounded:
only predicted-hot, actually-scattered windows are rewritten, once.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Sequence, Set, Tuple

from repro.core.windows import WindowId

# issue a sweep once its deadline is within `safety x` its estimated
# read time (late enough to spare the cache, early enough to finish)
_SLACK_SAFETY = 4.0
# a single-segment window counts as scattered when the byte span its
# records cover exceeds this multiple of the records' own bytes
_SCATTER_SPAN_RATIO = 1.5


@dataclass
class SegmentSweep:
    """One contiguous readahead over a single log segment."""
    sid: int
    keys: List[Tuple[Tuple[float, float], int]]    # BlockKeys
    span_bytes: int
    record_bytes: int
    deadline: float                # earliest stage_at among contributors
    windows: Set[WindowId] = field(default_factory=set)


@dataclass
class PlanResult:
    sweeps: List[SegmentSweep]                 # issue now, EDF order
    deferred_windows: Set[WindowId]            # replan next drive
    coalesce: List[WindowId]                   # rewrite contiguously


class SegmentPrefetchPlanner:
    """Maps predicted re-executions to segment sweeps and coalescing
    work. Stateless across windows except for the coalesce-once set."""

    def __init__(self, cost, *, budget_bytes: int = 16 << 20,
                 coalesce: bool = True,
                 coalesce_probability: float = 0.25,
                 slack_safety: float = _SLACK_SAFETY):
        self.cost = cost
        self.budget_bytes = max(int(budget_bytes), 1)
        self.coalesce = coalesce
        self.coalesce_probability = coalesce_probability
        self.slack_safety = slack_safety
        self._coalesced: Set[WindowId] = set()
        self.stats = {
            "sweeps_planned": 0, "sweeps_issued": 0, "sweeps_deferred": 0,
            "sweep_bytes_issued": 0, "coalesce_requests": 0,
        }

    def forget(self, window: WindowId) -> None:
        self._coalesced.discard(window)

    # ---------------------------------------------------------------- plan
    def plan(self, store,
             wants: Sequence[Tuple[WindowId, float, list, float]],
             now: float) -> PlanResult:
        """``wants``: (window, stage_at, storage block keys, p_reexec)
        rows for every prefetch-worthy window. Returns the sweeps to
        issue now, the windows to re-plan later, and the coalescing
        candidates."""
        key_meta: Dict[Tuple, Tuple[WindowId, float]] = {}
        all_keys = []
        for wid, stage_at, keys, _p in wants:
            for k in keys:
                key_meta[(tuple(k[0]), int(k[1]))] = (wid, stage_at)
                all_keys.append(k)
        placement = store.segments_for(all_keys)

        sweeps: List[SegmentSweep] = []
        for sid, items in placement.items():
            lo = min(off for _, off, _ in items)
            hi = max(off + length for _, off, length in items)
            sweep = SegmentSweep(
                sid=sid, keys=[k for k, _, _ in items],
                span_bytes=hi - lo,
                record_bytes=sum(length for _, _, length in items),
                deadline=float("inf"))
            for k, _, _ in items:
                meta = key_meta.get((tuple(k[0]), int(k[1])))
                if meta is not None:
                    sweep.windows.add(meta[0])
                    sweep.deadline = min(sweep.deadline, meta[1])
            sweeps.append(sweep)
        self.stats["sweeps_planned"] += len(sweeps)

        # EDF + cost model: a sweep waits while its deadline slack still
        # comfortably exceeds its estimated read time; the byte budget
        # caps one round's cache pressure
        sweeps.sort(key=lambda s: s.deadline)
        issue: List[SegmentSweep] = []
        deferred: Set[WindowId] = set()
        spent = 0
        for sw in sweeps:
            est_read = self.cost.delta_t_bytes(sw.span_bytes)
            slack = sw.deadline - now
            if slack > self.slack_safety * max(est_read, 1e-6) \
                    and spent + sw.span_bytes > self.budget_bytes:
                # far-out AND over budget: wait for a later drive
                self.stats["sweeps_deferred"] += 1
                deferred |= sw.windows
                continue
            if spent + sw.span_bytes > self.budget_bytes and issue:
                self.stats["sweeps_deferred"] += 1
                deferred |= sw.windows
                continue
            issue.append(sw)
            spent += sw.span_bytes
        self.stats["sweeps_issued"] += len(issue)
        self.stats["sweep_bytes_issued"] += spent
        issued_windows = set().union(*(s.windows for s in issue)) \
            if issue else set()
        deferred -= issued_windows

        coalesce = self._pick_coalesce(store, wants) if self.coalesce \
            else []
        return PlanResult(sweeps=issue, deferred_windows=deferred,
                          coalesce=coalesce)

    # ------------------------------------------------------------ coalesce
    def _pick_coalesce(self, store, wants) -> List[WindowId]:
        out: List[WindowId] = []
        for wid, _stage_at, keys, p in wants:
            # one wanted key is enough: window_scatter counts ALL of the
            # window's live storage records (m- and p-bucket spills), so
            # the authoritative scatter check below is what gates the
            # rewrite, not how many p-blocks this round wants
            if p < self.coalesce_probability or wid in self._coalesced \
                    or not keys:
                continue
            wk = tuple(keys[0][0])
            records, segments, span, rec_bytes = store.window_scatter(wk)
            if records < 2:
                continue
            scattered = segments > 1 or (
                rec_bytes > 0 and span > _SCATTER_SPAN_RATIO * rec_bytes)
            if scattered:
                self._coalesced.add(wid)
                self.stats["coalesce_requests"] += 1
                out.append(wid)
        return out
