"""Online lateness + staging-cost models for learned prefetching.

The fixed-margin scheme predicts *when* to pre-stage from one EWMA of
staging seconds per event. This module supplies what the planner needs
beyond that:

* ``LatenessModel`` — per key-class empirical lateness CDFs, fit with
  the same ``core.staleness.empirical_cdf`` the predictive-cleanup /
  staleness-trigger machinery already uses (Zapridou & Ailamaki's
  "model late-arrival rates online", reusing the paper's own fits). A
  window's re-execution probability at watermark age ``a`` is the
  class-mixture survival ``1 - F(a)`` weighted by the late-event key
  classes observed for that window — windows whose keys stopped
  arriving stop being prefetched, regardless of the global tail.
* ``LearnedCostModel`` — a drop-in for ``StagingCostModel`` (the engine
  feeds it through ``prestage.cost.observe``) extended with an online
  store-bandwidth estimate (``observe_bytes`` / ``delta_t_bytes``) that
  the planner prices segment sweeps with.
"""
from __future__ import annotations

from collections import OrderedDict, deque
from typing import Deque, Dict, Optional, Tuple

import numpy as np

from repro.core.staleness import empirical_cdf
from repro.core.windows import WindowId


class LearnedCostModel:
    """``StagingCostModel``-compatible cost estimate + bandwidth view.

    Per-event lead (``observe``/``delta_t``) follows the fixed model's
    contract — pessimistic ``+inf`` before the first observation, EWMA
    with a floor after — so the engine's ``prestage.cost.observe`` call
    and the heap-based plan timing need no changes. The bytes view
    (``observe_bytes``/``delta_t_bytes``) is fed by measured segment
    sweeps and prices the planner's bandwidth/slack decisions."""

    def __init__(self, *, prior_bandwidth_bytes_per_s: float = 64e6,
                 alpha: float = 0.3, floor_seconds: float = 1e-3):
        self.seconds_per_event = 1e-6
        self.alpha = alpha
        self.observations = 0
        self.floor_seconds = floor_seconds
        self._bandwidth = max(prior_bandwidth_bytes_per_s, 1.0)
        self.bandwidth_observations = 0

    # ------------------------------------------------ per-event (engine)
    def observe(self, seconds: float, events: int) -> None:
        if events <= 0:
            return
        per_event = seconds / events
        if self.observations == 0:
            self.seconds_per_event = per_event
        else:
            self.seconds_per_event = (
                self.alpha * per_event
                + (1 - self.alpha) * self.seconds_per_event)
        self.observations += 1

    def delta_t(self, events: int) -> float:
        if self.observations == 0:
            return float("inf")        # pessimistic first lead (§3.2)
        return max(self.seconds_per_event * max(events, 0),
                   self.floor_seconds)

    # ------------------------------------------------ bytes (planner)
    def observe_bytes(self, seconds: float, nbytes: int) -> None:
        """One measured store read (a segment sweep): update the
        bandwidth EWMA. Sub-microsecond timings are floored so a cached
        or page-cache-served sweep cannot drive the estimate to +inf."""
        if nbytes <= 0:
            return
        bw = nbytes / max(seconds, 1e-6)
        if self.bandwidth_observations == 0:
            self._bandwidth = bw
        else:
            self._bandwidth = (self.alpha * bw
                               + (1 - self.alpha) * self._bandwidth)
        self.bandwidth_observations += 1

    @property
    def bandwidth_bytes_per_s(self) -> float:
        return self._bandwidth

    def delta_t_bytes(self, nbytes: int) -> float:
        """Estimated seconds to read ``nbytes`` from the store."""
        return max(nbytes, 0) / self._bandwidth


class LatenessModel:
    """Per key-class empirical lateness CDFs, fit online.

    Late events arrive as ``(key, delay)`` samples; keys hash into
    ``num_classes`` classes, each keeping a bounded ring of recent
    delays. CDFs are re-fit lazily (every ``refit_every`` new samples
    per class) through ``core.staleness.empirical_cdf`` on a shared
    horizon that tracks the largest delay seen. Per-window class-count
    vectors (bounded LRU) weight the mixture when predicting one
    window's re-execution probability."""

    def __init__(self, *, num_classes: int = 8, max_samples: int = 4096,
                 refit_every: int = 128, grid_size: int = 256,
                 max_windows: int = 4096):
        self.num_classes = max(int(num_classes), 1)
        per_class = max(max_samples // self.num_classes, 64)
        self._delays: Tuple[Deque[float], ...] = tuple(
            deque(maxlen=per_class) for _ in range(self.num_classes))
        self._fresh = np.zeros(self.num_classes, np.int64)
        self.refit_every = max(int(refit_every), 1)
        self.grid_size = grid_size
        self._cdfs: Dict[int, Tuple[np.ndarray, np.ndarray]] = {}
        self._horizon = 1.0
        self._fit_horizon = 0.0
        self.samples = 0
        # window -> per-class late-event counts (bounded: oldest evicts)
        self._window_classes: "OrderedDict[WindowId, np.ndarray]" = \
            OrderedDict()
        self.max_windows = max_windows

    # ------------------------------------------------------------ updates
    def _class_of(self, keys: np.ndarray) -> np.ndarray:
        return np.abs(np.asarray(keys, np.int64)) % self.num_classes

    def observe(self, window: Optional[WindowId], keys: np.ndarray,
                delays: np.ndarray) -> None:
        """Record late-event delay samples (and their key classes) for
        ``window``. ``window=None`` updates only the class CDFs."""
        delays = np.asarray(delays, np.float64)
        if delays.size == 0:
            return
        classes = self._class_of(keys)
        self.samples += delays.size
        dmax = float(delays.max())
        if dmax > self._horizon:
            self._horizon = dmax
        for c in np.unique(classes):
            sel = delays[classes == c]
            self._delays[int(c)].extend(sel.tolist())
            self._fresh[int(c)] += sel.size
        if window is not None:
            counts = self._window_classes.get(window)
            if counts is None:
                if len(self._window_classes) >= self.max_windows:
                    self._window_classes.popitem(last=False)
                counts = np.zeros(self.num_classes, np.float64)
                self._window_classes[window] = counts
            else:
                self._window_classes.move_to_end(window)
            np.add.at(counts, classes, 1.0)

    def forget(self, window: WindowId) -> None:
        """Drop per-window state (the engine purged the window)."""
        self._window_classes.pop(window, None)

    # -------------------------------------------------------- predictions
    def _cdf(self, c: int) -> Optional[Tuple[np.ndarray, np.ndarray]]:
        buf = self._delays[c]
        if not buf:
            return None
        horizon = self._horizon * 1.05
        stale = (self._fresh[c] >= self.refit_every
                 or horizon > self._fit_horizon * 1.5)
        cached = self._cdfs.get(c)
        if cached is None or stale:
            cached = empirical_cdf(np.asarray(buf, np.float64), horizon,
                                   self.grid_size)
            self._cdfs[c] = cached
            self._fresh[c] = 0
            self._fit_horizon = max(self._fit_horizon, horizon)
        return cached

    def survival(self, c: int, age: float) -> float:
        """P(a late event of class ``c`` arrives later than ``age``)."""
        cdf = self._cdf(c)
        if cdf is None:
            return 1.0                 # no data: stay pessimistic
        grid, F = cdf
        return float(np.clip(1.0 - np.interp(age, grid, F), 0.0, 1.0))

    def reexec_probability(self, window: Optional[WindowId],
                           age: float) -> float:
        """P(more late events after watermark age ``age``) for
        ``window`` — the class-mixture survival weighted by the window's
        observed late-event classes (uniform over observed classes when
        the window is unknown). With no samples at all the model is
        pessimistic (1.0): the first re-execution is always worth
        prefetching, matching the fixed scheme's pessimistic first
        lead."""
        if self.samples == 0:
            return 1.0
        counts = None
        if window is not None:
            counts = self._window_classes.get(window)
        if counts is None or counts.sum() <= 0:
            weights = np.array([len(b) for b in self._delays], np.float64)
        else:
            weights = counts
        total = weights.sum()
        if total <= 0:
            return 1.0
        p = 0.0
        for c in np.nonzero(weights)[0]:
            p += weights[c] * self.survival(int(c), age)
        return float(np.clip(p / total, 0.0, 1.0))

    def expected_residual_delay(self, age: float, q: float = 0.5) -> float:
        """Conditional quantile of the next late-event delay given the
        window already aged ``age`` (pooled over classes) — the planner's
        slack extension when a staging deadline is not yet known."""
        pooled = [d for buf in self._delays for d in buf if d > age]
        if not pooled:
            return 0.0
        return float(np.quantile(np.asarray(pooled, np.float64), q) - age)
