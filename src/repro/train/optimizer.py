"""AdamW with global-norm clipping and a linear-warmup cosine schedule.

Implemented directly on pytrees (no optax dependency in this environment).
Optimizer moments inherit the parameter shardings, so the optimizer state
is ZeRO-sharded exactly like the params.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def schedule(cfg: OptConfig, step: jnp.ndarray) -> jnp.ndarray:
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = (step - cfg.warmup_steps) / jnp.maximum(
        cfg.total_steps - cfg.warmup_steps, 1)
    prog = jnp.clip(prog, 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
        1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def adamw_init(params) -> Dict[str, Any]:
    zeros = lambda p: jax.tree.map(jnp.zeros_like, p)
    return {"m": zeros(params), "v": zeros(params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(cfg: OptConfig, params, grads, opt_state
                 ) -> Tuple[Any, Dict[str, Any], Dict[str, jnp.ndarray]]:
    """One AdamW step. Returns (new_params, new_opt_state, stats)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    step = opt_state["step"] + 1
    lr = schedule(cfg, step)
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m2 = b1 * m.astype(jnp.float32) + (1 - b1) * g
        v2 = b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g)
        mh = m2 / bc1
        vh = v2 / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if p.ndim >= 2:                      # decoupled WD on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        p2 = p.astype(jnp.float32) - lr * delta
        return p2.astype(p.dtype), m2.astype(m.dtype), v2.astype(v.dtype)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    stats = {"grad_norm": gnorm, "lr": lr}
    return new_p, {"m": new_m, "v": new_v, "step": step}, stats
