"""Train step factory: loss -> grad -> (optional compression) -> AdamW.

The returned ``train_step(state, batch)`` is a pure jittable function; the
dry-run lowers it with NamedShardings derived from the model's logical spec
tree. Gradients are averaged over the batch axes implicitly by pjit (the
loss is a global mean); cross-pod gradient all-reduce appears on the ``pod``
axis of the multi-pod mesh via the parameter shardings being pod-replicated.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.distributed import sharding as shd
from repro.models.transformer import Model
from repro.train.optimizer import OptConfig, adamw_init, adamw_update


@dataclass
class TrainState:
    params: Any
    opt: Any

    def tree_flatten(self):
        return (self.params, self.opt), None


jax.tree_util.register_pytree_node(
    TrainState,
    lambda s: ((s.params, s.opt), None),
    lambda _, c: TrainState(params=c[0], opt=c[1]),
)


def init_train_state(model: Model, key) -> TrainState:
    params = model.init(key)
    return TrainState(params=params, opt=adamw_init(params))


def make_train_state_specs(model: Model):
    """Logical-axis spec tree matching TrainState structure."""
    pspecs = model.specs()
    return TrainState(
        params=pspecs,
        opt={"m": pspecs, "v": pspecs, "step": ()},
    )


def choose_microbatches(cfg, shape, mesh_cfg, profile,
                        act_budget_bytes: float = 2 << 30) -> int:
    """Pick gradient-accumulation depth so per-device live activations fit
    the budget. Two dominant terms per unit batch:
      * remat-scan residual carries: L x S x D x 2B
      * fp32 logits + grad + softmax workspace: S x Vp_loc x 4B x 3
    """
    from repro.distributed.sharding import pad_vocab
    axes = dict(zip(mesh_cfg.axes, mesh_cfg.shape))
    n_batch = 1
    for ax in profile.batch_axes:
        n_batch *= axes[ax]
    b_shard = max(shape.global_batch // max(n_batch, 1), 1)
    vp_loc = pad_vocab(cfg.vocab_size) // (
        axes.get("model", 1) if profile.vocab_tp else 1)
    per_unit = (cfg.num_layers * shape.seq_len * cfg.d_model * 2
                + shape.seq_len * vp_loc * 4 * 3)
    mu = 1
    while mu < b_shard and per_unit * (b_shard // mu) > act_budget_bytes:
        mu *= 2
    return mu


def choose_remat_group(cfg, shape, mesh_cfg, profile, mu,
                       carry_budget_bytes: float = 1 << 31) -> int:
    """If the flat per-layer carries still exceed the budget at the chosen
    microbatch depth, pick a sqrt-L remat group size (a divisor of L)."""
    import math
    axes = dict(zip(mesh_cfg.axes, mesh_cfg.shape))
    n_batch = 1
    for ax in profile.batch_axes:
        n_batch *= axes[ax]
    b_mu = max(shape.global_batch // max(n_batch, 1) // mu, 1)
    carry = b_mu * shape.seq_len * cfg.d_model * 2
    if cfg.num_layers * carry <= carry_budget_bytes:
        return 0
    L = cfg.num_layers
    target = max(int(math.sqrt(L)), 2)
    best = 1
    for g in range(2, L + 1):
        if L % g == 0 and abs(g - target) < abs(best - target):
            best = g
    return best if best > 1 else 0


def make_train_step(model: Model, opt_cfg: Optional[OptConfig] = None,
                    grad_transform: Optional[Callable] = None,
                    num_microbatches: int = 1):
    """Returns train_step(state, batch) -> (state, metrics).

    ``num_microbatches > 1``: the global batch is split on the leading axis
    and gradients are accumulated in fp32 (sharded like the params) across a
    ``lax.scan`` — bounding live activations at B/mu while keeping one
    optimizer step per call.

    ``grad_transform(grads) -> grads`` hook is where gradient compression
    (train/compression.py) plugs in.
    """
    opt_cfg = opt_cfg or OptConfig()

    def grads_and_metrics(params, batch):
        def loss_fn(p):
            return model.loss(p, batch)
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params)
        metrics = dict(metrics)
        metrics["loss"] = loss
        return grads, metrics

    def train_step(state: TrainState, batch) -> Tuple[TrainState, Dict]:
        if num_microbatches <= 1:
            grads, metrics = grads_and_metrics(state.params, batch)
        else:
            mu = num_microbatches

            def split(x):
                return x.reshape(mu, x.shape[0] // mu, *x.shape[1:])

            batch_mu = jax.tree.map(split, batch)

            def accum(carry, mb):
                g_acc, m_acc = carry
                g, m = grads_and_metrics(state.params, mb)
                g_acc = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_acc, g)
                m_acc = jax.tree.map(lambda a, b: a + b, m_acc, m)
                return (g_acc, m_acc), None

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            m0 = {"ce": jnp.float32(0), "aux": jnp.float32(0),
                  "ntok": jnp.float32(0), "loss": jnp.float32(0)}
            (grads, metrics), _ = jax.lax.scan(accum, (g0, m0), batch_mu)
            grads = jax.tree.map(lambda g: g / mu, grads)
            metrics = jax.tree.map(lambda m: m / mu, metrics)

        if grad_transform is not None:
            grads = grad_transform(grads)
        new_params, new_opt, stats = adamw_update(
            opt_cfg, state.params, grads, state.opt)
        metrics = dict(metrics)
        metrics.update(stats)
        return TrainState(params=new_params, opt=new_opt), metrics

    return train_step
