from repro.train.optimizer import adamw_init, adamw_update, OptConfig
from repro.train.train_step import TrainState, make_train_step, make_train_state_specs

__all__ = [
    "adamw_init", "adamw_update", "OptConfig",
    "TrainState", "make_train_step", "make_train_state_specs",
]
