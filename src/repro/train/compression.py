"""Gradient compression with error feedback (distributed-optimization).

On a real pod the cross-pod gradient all-reduce is the slowest collective
(DCN, not ICI). These transforms model int8 / top-k compression with an
error-feedback accumulator (Seide et al. 2014; Karimireddy et al. 2019):
the quantization residual is carried into the next step, preserving
convergence. The compress->decompress round trip here reproduces the exact
numerics the wire format would produce; pairing it with an int8
reduce-scatter is a backend detail recorded in DESIGN.md.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Tuple

import jax
import jax.numpy as jnp


def _int8_roundtrip(x: jnp.ndarray) -> jnp.ndarray:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q.astype(jnp.float32) * scale


def _topk_roundtrip(x: jnp.ndarray, frac: float) -> jnp.ndarray:
    flat = x.reshape(-1)
    k = max(int(flat.shape[0] * frac), 1)
    thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
    return jnp.where(jnp.abs(flat) >= thresh, flat, 0.0).reshape(x.shape)


@dataclass(frozen=True)
class CompressorConfig:
    kind: str = "int8"           # 'int8' | 'topk' | 'none'
    topk_frac: float = 0.01
    error_feedback: bool = True


def init_error_feedback(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_grads(cfg: CompressorConfig, grads, ef) -> Tuple[Any, Any]:
    """Returns (decompressed grads as sent on the wire, new EF state)."""
    if cfg.kind == "none":
        return grads, ef

    def one(g, e):
        g = g.astype(jnp.float32)
        target = g + (e if cfg.error_feedback else 0.0)
        if cfg.kind == "int8":
            sent = _int8_roundtrip(target)
        elif cfg.kind == "topk":
            sent = _topk_roundtrip(target, cfg.topk_frac)
        else:
            raise ValueError(cfg.kind)
        new_e = target - sent if cfg.error_feedback else e
        return sent, new_e

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(ef)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (treedef.unflatten([o[0] for o in out]),
            treedef.unflatten([o[1] for o in out]))


def compression_ratio(cfg: CompressorConfig) -> float:
    """Wire-bytes ratio vs fp32 (for the collective-roofline term)."""
    if cfg.kind == "int8":
        return 0.25
    if cfg.kind == "topk":
        return cfg.topk_frac * 2.0          # value + index
    return 1.0
