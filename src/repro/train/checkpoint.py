"""Fault-tolerant checkpointing: atomic, async, manifest-driven.

Layout (one directory per step):
    ckpt_dir/step_000100/
        manifest.json       tree structure, shapes, dtypes, step, metadata
        arrays.npz          flattened leaves keyed by tree path
    ckpt_dir/LATEST         text file with the newest complete step

Writes go to a ``.tmp`` directory first and are renamed only after fsync —
a crash mid-save never corrupts the previous checkpoint (restart reads
LATEST). ``AsyncCheckpointer`` snapshots device arrays to host, then
persists on a background thread so the train loop never blocks on storage
(the same decoupling the paper uses for destaging). Restore accepts a
target sharding tree, so a checkpoint taken on one mesh restores onto
another (elastic scaling).
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from pathlib import Path
from typing import Any, Dict, Optional

import jax
import numpy as np


def _flatten_with_paths(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = leaf
    return flat


def save_checkpoint(ckpt_dir: Path, state, step: int,
                    metadata: Optional[Dict] = None) -> Path:
    ckpt_dir = Path(ckpt_dir)
    ckpt_dir.mkdir(parents=True, exist_ok=True)
    final = ckpt_dir / f"step_{step:08d}"
    tmp = ckpt_dir / f".tmp_step_{step:08d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    flat = _flatten_with_paths(state)
    arrays = {k: np.asarray(v) for k, v in flat.items()}
    np.savez(tmp / "arrays.npz", **arrays)
    manifest = {
        "step": step,
        "metadata": metadata or {},
        "leaves": {k: {"shape": list(v.shape), "dtype": str(v.dtype)}
                   for k, v in arrays.items()},
        "saved_at": time.time(),
    }
    with open(tmp / "manifest.json", "w") as f:
        json.dump(manifest, f, indent=2)
        f.flush()
        os.fsync(f.fileno())
    if final.exists():
        shutil.rmtree(final)
    os.rename(tmp, final)
    latest = ckpt_dir / "LATEST"
    latest_tmp = ckpt_dir / ".LATEST.tmp"
    latest_tmp.write_text(final.name)
    os.replace(latest_tmp, latest)
    return final


def latest_checkpoint(ckpt_dir: Path) -> Optional[Path]:
    ckpt_dir = Path(ckpt_dir)
    latest = ckpt_dir / "LATEST"
    if not latest.exists():
        steps = sorted(ckpt_dir.glob("step_*"))
        return steps[-1] if steps else None
    path = ckpt_dir / latest.read_text().strip()
    return path if path.exists() else None


def restore_checkpoint(path: Path, like, shardings=None):
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs). ``shardings``: optional matching tree of
    NamedShardings — enables restoring onto a different mesh."""
    path = Path(path)
    with np.load(path / "arrays.npz") as z:
        arrays = {k: z[k] for k in z.files}
    flat_like = _flatten_with_paths(like)
    missing = set(flat_like) - set(arrays)
    if missing:
        raise ValueError(f"checkpoint missing leaves: {sorted(missing)[:5]}")
    flat_sh = _flatten_with_paths(shardings) if shardings is not None else {}

    restored = {}
    for k, leaf in flat_like.items():
        arr = arrays[k]
        sh = flat_sh.get(k)
        if sh is not None:
            restored[k] = jax.device_put(arr, sh)
        else:
            restored[k] = jax.numpy.asarray(arr)

    # rebuild the tree
    leaves_paths, treedef = jax.tree_util.tree_flatten_with_path(like)
    keys = ["/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                     for p in path_) for path_, _ in leaves_paths]
    return jax.tree_util.tree_unflatten(treedef, [restored[k] for k in keys])


def read_manifest(path: Path) -> Dict:
    with open(Path(path) / "manifest.json") as f:
        return json.load(f)


class AsyncCheckpointer:
    """Snapshot-to-host synchronously, persist asynchronously; keeps the
    newest ``keep`` checkpoints."""

    def __init__(self, ckpt_dir: Path, keep: int = 3):
        self.ckpt_dir = Path(ckpt_dir)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self.last_saved_step: Optional[int] = None

    def save(self, state, step: int, metadata: Optional[Dict] = None,
             block: bool = False) -> None:
        self.wait()
        # snapshot to host now (cheap) so training can mutate buffers
        host_state = jax.tree.map(lambda x: np.asarray(x), state)

        def work():
            save_checkpoint(self.ckpt_dir, host_state, step, metadata)
            self.last_saved_step = step
            self._gc()

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()
        if block:
            self.wait()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = sorted(self.ckpt_dir.glob("step_*"))
        for old in steps[:-self.keep]:
            shutil.rmtree(old, ignore_errors=True)
