"""Elastic scaling: reshard a train state onto a different mesh.

A checkpoint is mesh-agnostic (full arrays + manifest); growing or
shrinking the fleet is restore-with-new-shardings. ``elastic_reshard``
also handles live resharding (device arrays in, device arrays out) for
in-flight topology changes, and ``adjust_batch_schedule`` keeps the global
batch contract when the data-parallel degree changes.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax

from repro.configs.base import MeshConfig
from repro.distributed import sharding as shd
from repro.models.transformer import Model
from repro.train.train_step import TrainState, make_train_state_specs


def state_shardings(model: Model, mesh, mesh_cfg: MeshConfig,
                    global_batch: int):
    rules = shd.make_rules(model.cfg, mesh_cfg, global_batch)
    logical = make_train_state_specs(model)
    return jax.tree.map(
        lambda spec: jax.sharding.NamedSharding(
            mesh, shd.logical_to_pspec(spec, rules)),
        logical, is_leaf=lambda x: isinstance(x, tuple))


def elastic_reshard(state: TrainState, model: Model, new_mesh,
                    new_mesh_cfg: MeshConfig,
                    global_batch: int) -> TrainState:
    """Move a live train state onto a new mesh (gather + re-place)."""
    sh = state_shardings(model, new_mesh, new_mesh_cfg, global_batch)
    return jax.tree.map(lambda x, s: jax.device_put(x, s), state, sh)


def adjust_batch_schedule(global_batch: int, old_dp: int, new_dp: int,
                          step: int) -> Tuple[int, int]:
    """Keep the *global* batch invariant across a data-parallel resize.
    Returns (per_shard_batch, equivalent_step) — the sample counter
    (step * global_batch) is what must be preserved, so the step index
    carries over unchanged while per-shard batch rescales."""
    if global_batch % new_dp:
        raise ValueError(f"global_batch {global_batch} not divisible by "
                         f"new dp degree {new_dp}")
    return global_batch // new_dp, step
