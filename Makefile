# Tier-1 verification: the exact ROADMAP.md command, verbatim. Keep in
# sync with ROADMAP.md "Tier-1 verify".
verify:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} python -m pytest -x -q

# Benchmark entry point (CSV rows, one per paper table/figure).
bench:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} python benchmarks/run.py

.PHONY: verify bench
