# Tier-1 verification: the exact ROADMAP.md command, verbatim, followed
# by the multi-device suites on 8 simulated CPU devices. Keep the first
# recipe line in sync with ROADMAP.md "Tier-1 verify".
verify:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} python -m pytest -x -q
	$(MAKE) verify-storage
	$(MAKE) verify-multidevice
	$(MAKE) verify-pipeline
	$(MAKE) verify-prefetch
	$(MAKE) verify-splitk
	$(MAKE) verify-chaos
	$(MAKE) verify-obs

# Persistent p-bucket store suites, tmpdir-isolated (pytest tmp_path):
# storage unit tests (WAL group commit, footer rebuild, torn-tail
# recovery, tombstones, compaction bound, batched reads/readahead) plus
# the engine-level crash-recovery matrix (SIGKILL after an acknowledged
# commit / mid-segment, reopen + restore, differential oracle parity)
# and the compaction bound under purge soak. Also collected by plain
# `pytest` above; this target is the focused storage gate.
verify-storage:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} python -m pytest -x -q \
		tests/test_storage.py tests/test_storage_recovery.py

# Slot-sharding + differential-soak suites under a forced 8-device host
# platform (XLA splits the CPU into 8 simulated devices; the slot-sharded
# batched fold really runs under shard_map). These same files also run —
# single-device fallbacks only — inside plain `pytest` above. The block
# pool is DEFAULT-ON (AionConfig.block_pool), so both verify targets
# exercise the pooled configuration throughout; the soak + batch_exec
# matrices additionally pin pooled on/off explicitly.
verify-multidevice:
	XLA_FLAGS="--xla_force_host_platform_device_count=8$${XLA_FLAGS:+ $$XLA_FLAGS}" \
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} python -m pytest -x -q \
		tests/test_slot_sharding.py tests/test_soak_differential.py \
		tests/test_kernels.py tests/test_property.py \
		tests/test_batch_exec.py tests/test_block_pool.py

# Pipelined-engine gate: ingest/stage/fold overlap (futures, watermark
# fences, purge guard), I/O executor failure surfacing + weighted
# round-robin fairness, and multi-tenant multiplexing parity. Also
# collected by plain `pytest` above; this is the focused pipeline gate.
verify-pipeline:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} python -m pytest -x -q \
		tests/test_pipeline.py tests/test_staging_failures.py \
		tests/test_tenancy.py

# Learned-prefetch gate: lateness model CDFs, segment-sweep planning
# (EDF + budget/slack defer + coalesce nomination), LogBlockStore
# segment queries/sweeps/coalescing, WAL-coalesced group commits, and
# the fixed-vs-learned engine differential with readahead hit
# accounting. Also collected by plain `pytest` above; this is the
# focused prefetch gate.
verify-prefetch:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} python -m pytest -x -q \
		tests/test_prefetch.py tests/test_cleanup_proactive.py

# Split-K gate on 8 simulated devices: chunked partial-accumulator
# kernel parity sweeps (padded-row bit-exactness, empty/zero-chunk
# guards, merge identities, row-balanced sharded fold) plus the
# executor's split-K matrix and the skewed soak rows (splitk on/off,
# percentile's sorted-run batch contract).
verify-splitk:
	XLA_FLAGS="--xla_force_host_platform_device_count=8$${XLA_FLAGS:+ $$XLA_FLAGS}" \
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} python -m pytest -x -q \
		tests/test_kernels.py tests/test_batch_exec.py \
		tests/test_soak_differential.py \
		-k "splitk or merge_partials or pack_rows or percentile"

# Self-healing I/O gate: fault injector + retry/backoff taxonomy unit
# tests, degradation-ladder ordering, recovery glue (heartbeats, backup
# folds, restart/restore), and the chaos soaks — the full differential
# soak under >=5% injected store faults (oracle parity, zero lost
# windows, io.stats.gave_up == 0) plus the poison -> restore -> replay
# restart soak. Also collected by plain `pytest` above; this is the
# focused robustness gate.
verify-chaos:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} python -m pytest -x -q \
		tests/test_faults.py tests/test_fault_serve.py
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} python -m pytest -x -q \
		tests/test_soak_differential.py -k "chaos"

# Observability gate: metrics registry semantics (typed instruments,
# label children, legacy dict/attribute adapters), bounded series caps,
# thread-safe executor counters under concurrent hammering, structured
# tracing (explicit cross-thread parent handoff, per-attempt retry
# events, bounded ring), the Prometheus/JSON exporters, and the
# one-call engine.observability() surface incl. multi-tenant coverage.
# Also collected by plain `pytest` above; this is the focused obs gate.
verify-obs:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} python -m pytest -x -q \
		tests/test_obs.py

# Benchmark entry point (CSV rows, one per paper table/figure).
bench:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} python benchmarks/run.py

# Pooled vs device-concat gather benchmark; refreshes BENCH_q2_gather.json
bench-gather:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} python benchmarks/q2_throughput.py --gather

# Memory + storage-tier benchmark; refreshes BENCH_q1_memory.json (Q1
# rows plus log-vs-npz spill pressure: write amplification, bytes
# written/read/compacted, batched p-bucket fetch latency)
bench-q1:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} python benchmarks/q1_memory.py

# Staleness benchmark; refreshes BENCH_q4_staleness.json (trigger rows
# plus the store-backed late re-execution probe)
bench-q4:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} python benchmarks/q4_staleness.py

# Fixed-vs-learned prefetch probe only; merges a "prefetch_probe"
# section (readahead hit rate, learned_vs_fixed staleness ratio) into
# the existing BENCH_q4_staleness.json
bench-prefetch:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} python benchmarks/q4_staleness.py --prefetch

# Fault-injection probe only (0% / 2% / 10% injected store faults,
# degradation ladder on vs off); merges a "fault_probe" section into
# the existing BENCH_q4_staleness.json
bench-faults:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} python benchmarks/q4_staleness.py --faults

# Pipelined vs synchronous fold benchmark (cold p-blocks, 8 due
# windows); merges a "pipeline" section into BENCH_q2_gather.json
bench-pipeline:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} python benchmarks/q2_throughput.py --pipeline

# Split-K vs stripe fold on the Zipf-skewed growing-late-table workload;
# merges a "splitk_vs_stripe" section into BENCH_q2_gather.json
bench-skew:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} python benchmarks/q2_throughput.py --skew

# Tracing-overhead probe (identical fold-bound loop at trace sample
# rate 0.0 vs 1.0, <5% acceptance bar); merges a "tracing_overhead"
# section into BENCH_q2_gather.json
bench-obs:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} python benchmarks/q2_throughput.py --obs

.PHONY: verify verify-storage verify-multidevice verify-pipeline \
	verify-prefetch verify-splitk verify-chaos verify-obs bench \
	bench-gather bench-q1 bench-q4 bench-prefetch bench-faults \
	bench-pipeline bench-skew bench-obs
