# Tier-1 verification: the exact ROADMAP.md command, verbatim, followed
# by the multi-device suites on 8 simulated CPU devices. Keep the first
# recipe line in sync with ROADMAP.md "Tier-1 verify".
verify:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} python -m pytest -x -q
	$(MAKE) verify-multidevice

# Slot-sharding + differential-soak suites under a forced 8-device host
# platform (XLA splits the CPU into 8 simulated devices; the slot-sharded
# batched fold really runs under shard_map). These same files also run —
# single-device fallbacks only — inside plain `pytest` above. The block
# pool is DEFAULT-ON (AionConfig.block_pool), so both verify targets
# exercise the pooled configuration throughout; the soak + batch_exec
# matrices additionally pin pooled on/off explicitly.
verify-multidevice:
	XLA_FLAGS="--xla_force_host_platform_device_count=8$${XLA_FLAGS:+ $$XLA_FLAGS}" \
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} python -m pytest -x -q \
		tests/test_slot_sharding.py tests/test_soak_differential.py \
		tests/test_kernels.py tests/test_property.py \
		tests/test_batch_exec.py tests/test_block_pool.py

# Benchmark entry point (CSV rows, one per paper table/figure).
bench:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} python benchmarks/run.py

# Pooled vs device-concat gather benchmark; refreshes BENCH_q2_gather.json
bench-gather:
	PYTHONPATH=src$${PYTHONPATH:+:$$PYTHONPATH} python benchmarks/q2_throughput.py --gather

.PHONY: verify verify-multidevice bench bench-gather
