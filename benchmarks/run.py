"""Benchmark entry point: one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (scaffold contract): for
engine benchmarks us_per_call is microseconds per ingested event; derived
carries the headline metric of that table.
"""
from __future__ import annotations

import json
import sys
import time
from pathlib import Path


def _csv(name, us_per_call, derived):
    print(f"{name},{us_per_call:.3f},{derived}")


def main() -> None:
    t_all = time.time()
    from benchmarks import q1_memory, q2_throughput, q3_ablation, q4_staleness

    # ---- Q1: memory pressure (Fig. 2)
    for r in q1_memory.run():
        name = f"q1_{r['workload']}_{r['backend']}_pw{r['past_windows']}"
        derived = (f"median_device_mb={r['median_device_mb']:.1f};"
                   f"oom_at={r['oom_at_watermark']}")
        _csv(name, 1e6 * r["seconds"] / 15000, derived)

    # ---- Q2: throughput overhead (Figs. 3-5)
    for r in q2_throughput.run():
        tag = "late" if r["late_included"] else "normal"
        name = f"q2_{r['workload']}_{r['backend']}_{tag}"
        _csv(name, 1e6 / max(r["events_per_sec"], 1e-9),
             f"events_per_sec={r['events_per_sec']:.0f};"
             f"stall_s={r['fetch_stall_s']}")

    # ---- Q3: per-optimization ablations (Fig. 8)
    for r in q3_ablation.run():
        name = f"q3_{r['variant']}"
        _csv(name, 1e6 / max(r["events_per_sec"], 1e-9),
             f"sim_io_s={r['sim_io_s']};stall_s={r['fetch_stall_s']};"
             f"peak_mb={r['peak_device_mb']:.1f};"
             f"preempt={r['preemptions']}")

    # ---- Q4: staleness trigger (Fig. 9)
    q4 = q4_staleness.run()
    for r in q4["staleness_vs_executions"]:
        _csv(f"q4_maxstaleness_k{r['k']}", 0.0,
             f"aion={r['aion']:.4f};deltat={r['deltat']:.4f};"
             f"deltaev={r['deltaev']:.4f}")
    for r in q4["executions_for_bounds"]:
        _csv(f"q4_execs_{r['dist']}_b{r['bound']}", 0.0,
             f"aion={r['aion']};deltat={r['deltat']};"
             f"deltaev={r['deltaev']}")

    # ---- Roofline (from dry-run records, if present)
    dryrun = Path("experiments/dryrun")
    if dryrun.exists() and any(dryrun.glob("*.json")):
        from benchmarks import roofline
        rows = roofline.main(quiet=True)
        for r in rows:
            name = f"roofline_{r['mesh']}_{r['arch']}_{r['shape']}"
            bound_s = max(r["compute_s"], r["memory_s"], r["collective_s"])
            _csv(name, bound_s * 1e6,
                 f"dominant={r['dominant']};frac={r['roofline_fraction']:.3f};"
                 f"fits={r['fits_hbm']}")

    print(f"# total benchmark wall time: {time.time()-t_all:.1f}s",
          file=sys.stderr)


if __name__ == "__main__":
    main()
