"""Q3 (paper Fig. 8): contribution of each optimization, on ``average``.

  aion-full      pre-staging + chunked transfers + single prioritized I/O
  no-pre-stgng   proactive caching off: staging starts at execution time
  no-mt-srlz     monolithic transfers (chunk_blocks -> inf): destaging can't
                 be chunk-preempted and staging DMAs can't interleave
                 (TPU analogue of single-thread serialization)
  no-sqntl-io    thread-pool I/O with no global priority order

Measured under a late-heavy phase so staging is on the critical path; we
add a simulated persistent-tier cost (seconds/byte) so the I/O-exposure
differences are deterministic rather than host-noise."""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from repro.configs.base import AionConfig
from repro.configs.workloads import AVERAGE
from repro.core import StreamEngine, TumblingWindows
from repro.core.operators import make_operator
from repro.core.triggers import DeltaTTrigger
from repro.data.generators import make_generator

VARIANTS = {
    "aion-full": dict(prestage_enabled=True, chunk_blocks=4,
                      sequential_io=True),
    "no-pre-stgng": dict(prestage_enabled=False, chunk_blocks=4,
                         sequential_io=True),
    "no-mt-srlz": dict(prestage_enabled=True, chunk_blocks=10**9,
                       sequential_io=True),
    "no-sqntl-io": dict(prestage_enabled=True, chunk_blocks=4,
                        sequential_io=False),
}


def run_one(variant: str, past_windows: int = 4) -> Dict:
    kw = VARIANTS[variant]
    gen = make_generator(AVERAGE, seed=7)
    aion = AionConfig(block_size=128)
    op = make_operator("average", aion.block_size, gen.width)
    eng = StreamEngine(
        assigner=TumblingWindows(AVERAGE.window_duration),
        operator=op, aion=aion, value_width=gen.width,
        device_budget_bytes=64 << 20,
        trigger=DeltaTTrigger(executions=2),
        simulated_seconds_per_byte=1e-8,       # ~100 MB/s persistent tier
        **kw,
    )
    wd = AVERAGE.window_duration
    # prime the lateness estimator so the re-execution horizon is short and
    # late re-executions actually fire within the measured run
    eng.cleanup.min_history = 10
    eng.cleanup.coverage = 0.9
    eng.cleanup.observe(np.random.default_rng(0).uniform(0.5, 1.5 * wd,
                                                         2000))
    now = past_windows * wd
    t0 = time.time()
    events = 0
    for _ in range(10):
        batch = gen.batch(1500, now)
        batch.timestamps = np.maximum(batch.timestamps,
                                      now - past_windows * wd)
        eng.ingest(batch, now)
        events += len(batch)
        eng.advance_watermark(now, now)
        # drive late re-executions inside the horizon; pace the polls in
        # wall time (~100x faster than real time) so the persistent-tier
        # channel has wall-clock room to work ahead
        for t in np.linspace(now + wd / 4, now + wd, 4):
            eng.poll(t)
            time.sleep(0.05)
        now += wd
    eng.io.drain()
    dt = time.time() - t0
    obs = eng.observability()
    out = {
        "variant": variant,
        "events_per_sec": events / dt,
        "late_execs": obs["engine"]["late_executions"],
        "fetch_stall_s": round(obs["engine"]["fetch_stall_seconds"], 4),
        "sim_io_s": round(obs["io"]["simulated_io_seconds"], 4),
        "peak_device_mb": eng.budget.peak_bytes / 2**20,
        "staged_blocks": obs["io"]["staged_blocks"],
        "preemptions": obs["io"]["preemptions"],
    }
    eng.close()
    return out


def run() -> List[Dict]:
    return [run_one(v) for v in VARIANTS]


if __name__ == "__main__":
    for r in run():
        print(r)
