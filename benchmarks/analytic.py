"""Analytic FLOPs / HBM-bytes / collective-bytes model per dry-run cell.

Why analytic: XLA's ``cost_analysis()`` on this backend does not multiply
``while``-loop body costs by trip count, and every hot loop here (layer
scan, microbatch scan, attention kv scan, SSD chunk scan) is a while loop —
so HLO FLOPs under-count by the product of trip counts. We therefore
reconstruct the executed-FLOPs model from the exact program structure
(validated in ``tests/test_roofline_model.py`` against ``cost_analysis``
of a loop-free single-layer lowering) and use the HLO only for the
collective *schedule* (which ops appear).

Conventions: FLOPs count multiply-adds as 2; bytes are per-device; ring
collective cost of a tensor of global (already per-shard) bytes M over n
participants ≈ M·(n-1)/n per device for all-gather/reduce-scatter and
2·M·(n-1)/n for all-reduce.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional

from repro.configs.base import MeshConfig, ModelConfig, ShapeConfig
from repro.distributed.sharding import ShardingProfile, pad_vocab

# v5e targets (per chip)
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s per link
ICI_LINKS = 4                # 2D torus: 2 axes x 2 directions


@dataclass
class CellCosts:
    flops_per_device: float = 0.0        # executed (incl. remat recompute)
    useful_flops_per_device: float = 0.0 # single fwd+bwd, causal-exact
    hbm_bytes_per_device: float = 0.0
    coll_bytes_per_device: Dict[str, float] = field(default_factory=dict)
    breakdown: Dict[str, float] = field(default_factory=dict)

    @property
    def coll_total(self) -> float:
        return sum(self.coll_bytes_per_device.values())


def _axes(mesh: MeshConfig) -> Dict[str, int]:
    return dict(zip(mesh.axes, mesh.shape))


def _mlp_mats(cfg: ModelConfig) -> int:
    return 3 if cfg.mlp_variant == "swiglu" else 2


def layer_flops_per_token(cfg: ModelConfig, seq: int, *, causal_full: bool,
                          kind: str) -> Dict[str, float]:
    """Forward FLOPs per token for one layer, by component.

    ``causal_full``: the blocked XLA attention computes the full (masked)
    S^2 score matrix — 'computed' counts that; 'useful' halves it.
    """
    d, dh = cfg.d_model, cfg.resolved_head_dim
    h, hkv = cfg.num_heads, cfg.num_kv_heads
    out: Dict[str, float] = {}
    if cfg.has_attention:
        proj = 2 * d * (h * dh) * 2 + 2 * d * (hkv * dh) * 2  # q,o + k,v
        out["attn_proj"] = proj
        kv_span = seq if not cfg.attn_window else min(cfg.attn_window, seq)
        if kind == "decode":
            score = 4 * h * dh * kv_span          # one token vs cache
        else:
            score = 4 * h * dh * kv_span          # per token: S (or W) keys
        out["attn_score_computed"] = score if (causal_full or cfg.attn_window
                                               or kind == "decode") \
            else score
        out["attn_score_useful"] = score / 2 if (kind != "decode"
                                                 and not cfg.attn_window) \
            else score
    if cfg.ssm.enabled:
        d_inner = cfg.ssm.expand * d
        nh = d_inner // cfg.ssm.head_dim
        p, n = cfg.ssm.head_dim, cfg.ssm.state_size
        q = cfg.ssm.chunk_size
        proj = 2 * d * (2 * d_inner + 2 * n + nh) + 2 * d_inner * d
        out["ssd_proj"] = proj
        if kind == "decode":
            out["ssd_scan"] = 2 * nh * p * n * 2   # state update + readout
        else:
            # intra-chunk: scores 2*Q*n + y_intra 2*Q*nh*p per token;
            # inter-chunk + state: 2*nh*p*n*2 per token
            out["ssd_scan"] = 2 * q * n + 2 * q * nh * p + 4 * nh * p * n
    if cfg.moe.enabled:
        e, k, cf = cfg.moe.num_experts, cfg.moe.top_k, cfg.moe.capacity_factor
        out["router"] = 2 * d * e
        out["moe_ffn"] = 2 * _mlp_mats(cfg) * d * cfg.d_ff * k * cf
    elif cfg.d_ff:
        out["mlp"] = 2 * _mlp_mats(cfg) * d * cfg.d_ff
    return out


def cell_costs(cfg: ModelConfig, shape: ShapeConfig, mesh: MeshConfig,
               profile: ShardingProfile, mu: int = 1,
               remat_group: int = 0,
               variant: Optional[Dict[str, object]] = None) -> CellCosts:
    variant = variant or {}
    ax = _axes(mesh)
    chips = mesh.num_devices
    model_n = ax.get("model", 1)
    data_n = ax.get("data", 1)
    pod_n = ax.get("pod", 1)
    n_batch_shards = 1
    for a in profile.batch_axes:
        n_batch_shards *= ax[a]

    B, S = shape.global_batch, shape.seq_len
    vp = pad_vocab(cfg.vocab_size)
    L = cfg.num_layers
    kind = shape.kind
    tokens_global = B * (1 if kind == "decode" else S)
    # frontends add encoder tokens (audio) or patch positions (vlm)
    enc_tokens = B * cfg.frontend_tokens if cfg.encoder_layers else 0

    costs = CellCosts()
    comp = layer_flops_per_token(cfg, S, causal_full=True, kind=kind)
    fwd_layer_flops = sum(v for k, v in comp.items()
                          if k != "attn_score_useful")
    useful_layer = sum(v for k, v in comp.items()
                       if k != "attn_score_computed")

    # unembed + embed
    head_flops = 2 * cfg.d_model * vp

    # ----- executed-FLOPs multiplier from the remat structure
    if kind == "train":
        # fwd(1) + remat-recompute(1) + bwd(2) [+ group recompute(1)]
        recompute = 1.0 if cfg.remat != "dots" and \
            variant.get("remat") != "dots" else 0.35
        if variant.get("remat") == "dots":
            recompute = 0.35        # only non-dot ops recompute
        mult = 3.0 + recompute + (1.0 if remat_group > 1 else 0.0)
        # double-checkpointed attention scores recompute once more in bwd
        attn_extra = comp.get("attn_score_computed", 0.0) * 1.0
    else:
        mult = 1.0
        attn_extra = 0.0
    if variant.get("causal_skip") and cfg.has_attention \
            and not cfg.attn_window and kind != "decode":
        # executed score tiles drop to the causal half (+half-tile diag)
        saved = comp.get("attn_score_computed", 0.0) * (0.5 - 0.5 / 8)
        fwd_layer_flops -= saved
        attn_extra *= 0.5

    total_fwd = tokens_global * (L * fwd_layer_flops + head_flops) \
        + enc_tokens * cfg.encoder_layers * fwd_layer_flops
    executed = total_fwd * mult + tokens_global * L * attn_extra
    useful = tokens_global * (L * useful_layer + head_flops) \
        * (3.0 if kind == "train" else 1.0)
    costs.flops_per_device = executed / chips
    costs.useful_flops_per_device = useful / chips
    costs.breakdown["fwd_flops_global"] = total_fwd
    costs.breakdown["executed_mult"] = mult

    # ----- HBM bytes (leading terms, per device)
    param_el_bytes = 2 if variant.get("param_dtype") == "bfloat16" else 4
    param_bytes_global = cfg.param_count() * param_el_bytes
    params_local = param_bytes_global / (data_n * (model_n if
                                         (profile.mlp_tp or profile.attn_tp)
                                         else 1))
    act_bytes_tok = cfg.d_model * 2
    tokens_local = tokens_global / max(n_batch_shards, 1)
    passes = 3 if kind == "train" else 1
    hbm = 0.0
    # weight traffic: each µbatch streams the (gathered) layer weights
    weight_stream = (param_bytes_global / max(model_n, 1)) \
        * (mu if kind == "train" else 1) * passes
    hbm += weight_stream
    hbm += tokens_local * L * act_bytes_tok * 2 * passes
    kv_bytes_per_el = 1.0 + 2.0 / cfg.resolved_head_dim \
        if variant.get("kv_bits") == 8 else 2.0
    if kind == "decode" and cfg.has_attention:
        cache_tok = 2 * L * cfg.num_kv_heads * cfg.resolved_head_dim \
            * kv_bytes_per_el
        cache_local = (B / max(n_batch_shards, 1)) * S * cache_tok \
            / (model_n if profile.kv_seq_shard else 1)
        # read whole cache + (masked full write | per-shard DUS ~0)
        write_factor = 0.0 if variant.get("kv_dus") else 1.0
        hbm += cache_local * (1 + write_factor)
        costs.breakdown["cache_local_bytes"] = cache_local
    if kind == "decode" and cfg.ssm.enabled:
        d_inner = cfg.ssm.expand * cfg.d_model
        nh = d_inner // cfg.ssm.head_dim
        state = (B / max(n_batch_shards, 1)) * L * nh * cfg.ssm.head_dim \
            * cfg.ssm.state_size * 4
        hbm += 2 * state / (model_n if profile.ssd_tp else 1)
    costs.hbm_bytes_per_device = hbm

    # ----- collective bytes (per device), ring model
    coll: Dict[str, float] = {"all-gather": 0.0, "reduce-scatter": 0.0,
                              "all-reduce": 0.0}
    rf = lambda n: (n - 1) / max(n, 1)
    if kind == "train":
        # FSDP param all-gather per µbatch (fwd + bwd recompute) + grad RS
        pl_ = param_bytes_global / (model_n if (profile.mlp_tp or
                                                profile.attn_tp) else 1)
        coll["all-gather"] += 2 * mu * (pl_ / data_n) * rf(data_n) * 2 / 2
        coll["all-gather"] += (1 if remat_group > 1 else 0) * mu \
            * (pl_ / data_n) * rf(data_n)
        coll["reduce-scatter"] += (pl_ / data_n) * rf(data_n)
        if pod_n > 1:
            wire = 0.25 if variant.get("compress_grads") else 1.0
            coll["all-reduce"] += 2 * (param_bytes_global / chips) \
                * rf(pod_n) * wire
    # TP activation collectives per layer per pass
    if profile.mlp_tp or profile.attn_tp or profile.expert_tp or profile.ssd_tp:
        tp_events = 0
        if profile.attn_tp:
            tp_events += 1                   # o-proj psum
        if profile.mlp_tp or profile.expert_tp:
            tp_events += 1                   # down-proj / moe combine psum
        if profile.ssd_tp:
            tp_events += 1
        act_local = tokens_local * act_bytes_tok
        n_pass = (mult if kind == "train" else 1)
        coll["all-reduce"] += 2 * tp_events * L * act_local * rf(model_n) \
            * n_pass
    if kind == "decode" and profile.kv_seq_shard and cfg.has_attention:
        # cross-shard softmax combine per layer
        qout = (B / max(n_batch_shards, 1)) * cfg.num_heads \
            * cfg.resolved_head_dim * 4
        coll["all-reduce"] += 2 * L * qout * rf(model_n) * 2
    if profile.vocab_tp:
        ce_bytes = tokens_local * 4 * 2      # logsumexp + max over vocab
        coll["all-reduce"] += 2 * ce_bytes * rf(model_n) \
            * (mu if kind == "train" else 1)
    costs.coll_bytes_per_device = coll
    return costs


def roofline_terms(costs: CellCosts) -> Dict[str, float]:
    compute_s = costs.flops_per_device / PEAK_FLOPS
    memory_s = costs.hbm_bytes_per_device / HBM_BW
    coll_s = costs.coll_total / (ICI_BW * ICI_LINKS)
    dominant = max((("compute", compute_s), ("memory", memory_s),
                    ("collective", coll_s)), key=lambda kv: kv[1])[0]
    bound = max(compute_s, memory_s, coll_s)
    frac = (costs.useful_flops_per_device / PEAK_FLOPS) / bound \
        if bound > 0 else 0.0
    return {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": coll_s,
        "dominant": dominant,
        "roofline_fraction": frac,      # useful-FLOPs MFU bound by max term
    }
