"""§Roofline: three-term roofline per (arch x shape x mesh) cell.

Reads the dry-run records (memory analysis + collective schedule from the
compiled HLO) and joins them with the analytic cost model
(``benchmarks.analytic`` — see its docstring for why HLO FLOPs cannot be
used directly with while-loops). Emits ``experiments/roofline.json`` and a
markdown table for EXPERIMENTS.md.

Memory-fit note: CPU jax does not implement buffer donation, so decode
temp double-counts the donated cache; projected TPU usage subtracts the
two un-aliased cache copies (documented per cell as ``projected_hbm``).
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, List, Optional

from benchmarks.analytic import (
    HBM_BW, ICI_BW, ICI_LINKS, PEAK_FLOPS, cell_costs, roofline_terms,
)
from repro.configs import ARCHS, SHAPES_BY_NAME
from repro.configs.base import MULTI_POD_MESH, SINGLE_POD_MESH
from repro.distributed import sharding as shd
from repro.train.train_step import choose_microbatches, choose_remat_group

DRYRUN_DIR = Path("experiments/dryrun")
OUT_JSON = Path("experiments/roofline.json")

HBM_PER_CHIP = 16 << 30

ADVICE = {
    "compute": ("cut executed FLOPs: recover the 2x causal-masking waste in "
                "blocked attention, or drop a remat level"),
    "memory": ("cut HBM traffic: fuse weight streams (larger µbatch), "
               "quantize the KV cache, or shard the dominant resident "
               "buffer further"),
    "collective": ("cut collective bytes: overlap FSDP gathers with compute,"
                   " compress cross-pod gradients, or move TP psums to "
                   "reduce-scatter form"),
}


def analyze_cell(rec: Dict) -> Optional[Dict]:
    arch, shape_name = rec["arch"], rec["shape"]
    cfg = ARCHS[arch]
    shape = SHAPES_BY_NAME[shape_name]
    mesh = MULTI_POD_MESH if "pod" in rec["mesh"]["axes"] else SINGLE_POD_MESH
    profile = shd.sharding_profile(cfg, mesh, shape.global_batch,
                                   shape.seq_len, shape.kind)
    mu = rec.get("profile", {}).get("num_microbatches", 1)
    rg = rec.get("profile", {}).get("remat_group", 0)
    costs = cell_costs(cfg, shape, mesh, profile, mu=mu, remat_group=rg,
                       variant=rec.get("variant") or {})
    terms = roofline_terms(costs)

    ma = rec.get("memory_analysis", {})
    args_b = ma.get("argument_size_in_bytes", 0)
    temp_b = ma.get("temp_size_in_bytes", 0)
    cache_b = rec.get("cache_bytes_per_device", 0)
    state_b = rec.get("state_bytes_per_device", 0)
    if shape.kind == "decode":
        # donated cache appears twice un-aliased on the CPU backend
        projected = args_b + temp_b - 2 * cache_b
    elif shape.kind == "train":
        # donated TrainState aliases in/out on TPU; CPU counts a copy
        projected = args_b + temp_b - state_b
    else:
        projected = args_b + temp_b
    model_flops = 6 * cfg.active_param_count() * shape.global_batch * (
        1 if shape.kind == "decode" else shape.seq_len)
    hlo_exec = costs.flops_per_device * rec["chips"]
    return {
        "cell": f"{rec['mesh']['axes'] and ('multi' if 'pod' in rec['mesh']['axes'] else 'single')}__{arch}__{shape_name}",
        "arch": arch, "shape": shape_name,
        "mesh": "multi" if "pod" in rec["mesh"]["axes"] else "single",
        "chips": rec["chips"], "kind": shape.kind,
        "compute_s": terms["compute_s"],
        "memory_s": terms["memory_s"],
        "collective_s": terms["collective_s"],
        "dominant": terms["dominant"],
        "roofline_fraction": terms["roofline_fraction"],
        "model_flops": model_flops,
        "executed_flops": hlo_exec,
        "model_over_executed": model_flops / hlo_exec if hlo_exec else 0,
        "projected_hbm_bytes": projected,
        "fits_hbm": projected <= HBM_PER_CHIP,
        "hlo_collectives": rec.get("collectives", {}),
        "analytic_collective_bytes": costs.coll_bytes_per_device,
        "mu": mu, "remat_group": rg,
        "profile_notes": rec.get("profile", {}).get("notes", []),
        "advice": ADVICE[terms["dominant"]],
    }


def main(dryrun_dir: Path = DRYRUN_DIR, out: Path = OUT_JSON,
         quiet: bool = False) -> List[Dict]:
    rows = []
    for f in sorted(dryrun_dir.glob("*.json")):
        if f.name == "skipped.json":
            continue
        rec = json.loads(f.read_text())
        if "error" in rec:
            continue
        row = analyze_cell(rec)
        if row:
            rows.append(row)
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(rows, indent=2))
    if not quiet:
        hdr = (f"{'cell':55s} {'dom':10s} {'comp(ms)':>9s} {'mem(ms)':>9s} "
               f"{'coll(ms)':>9s} {'RL-frac':>8s} {'fits':>5s}")
        print(hdr)
        for r in sorted(rows, key=lambda r: (r['mesh'], r['arch'],
                                             r['shape'])):
            print(f"{r['mesh']+'__'+r['arch']+'__'+r['shape']:55s} "
                  f"{r['dominant']:10s} {r['compute_s']*1e3:9.3f} "
                  f"{r['memory_s']*1e3:9.3f} {r['collective_s']*1e3:9.3f} "
                  f"{r['roofline_fraction']:8.3f} "
                  f"{'y' if r['fits_hbm'] else 'N':>5s}")
    return rows


if __name__ == "__main__":
    main()
