"""Q2 (paper Figs. 3-5): AION's ingestion/processing-rate overhead vs the
in-memory baseline when everything fits in memory.

Also benchmarks the batched multi-window execution path
(``fold_benchmark``): with many concurrent due windows, folding them in
one device pass vs one ``execute_window`` per window — and, with
``--devices N``, the slot-sharded multi-device fold vs the single-device
batched fold on N simulated CPU devices
(``XLA_FLAGS=--xla_force_host_platform_device_count=N``, which must be
set before jax imports: repro imports here are function-local so the
``__main__`` argparse can set it first)."""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

EVENTS_PER_WM = 1500
N_WATERMARKS = 8


def run_one(workload, baseline: bool, include_late: bool) -> Dict:
    from repro.configs.base import AionConfig
    from repro.core import InMemoryPolicy, StreamEngine, TumblingWindows
    from repro.core.operators import make_operator
    from repro.core.triggers import DeltaTTrigger
    from repro.data.generators import make_generator
    gen = make_generator(workload, seed=3)
    aion = AionConfig(block_size=1024)
    kw = {}
    if workload.operator == "stock":
        kw = {"num_keys": workload.num_keys}
    elif workload.operator == "lrb":
        kw = {"num_segments": workload.num_keys}
    elif workload.operator == "bigrams":
        kw = {"vocab": 64}
    op = make_operator(workload.operator, aion.block_size, gen.width, **kw)
    eng = StreamEngine(
        assigner=TumblingWindows(workload.window_duration),
        operator=op, aion=aion, value_width=gen.width,
        device_budget_bytes=512 << 20,       # fits fully in memory (Q2)
        policy=InMemoryPolicy() if baseline else None,
        trigger=DeltaTTrigger(executions=1),
    )
    wd = workload.window_duration
    now = 4 * wd
    ingested = 0
    # warmup
    eng.ingest(gen.batch(200, now), now)
    eng.advance_watermark(now, now)
    t0 = time.time()
    for _ in range(N_WATERMARKS):
        batch = gen.batch(EVENTS_PER_WM, now)
        if not include_late:
            batch = batch.select(batch.timestamps >= now - wd)
        eng.ingest(batch, now)
        ingested += len(batch)
        eng.advance_watermark(now, now)
        eng.poll(now)
        now += wd
    eng.io.drain()
    dt = time.time() - t0
    eng.close()
    return {
        "workload": workload.name,
        "backend": "baseline" if baseline else "aion",
        "late_included": include_late,
        "events_per_sec": ingested / dt,
        "processed_windows": eng.metrics.live_executions
        + eng.metrics.late_executions,
        "fetch_stall_s": round(eng.metrics.fetch_stall_seconds, 4),
        "batch_occupancy": round(eng.metrics.mean_batch_occupancy, 2),
        "device_s_per_exec": round(
            eng.metrics.device_seconds_per_execution, 6),
    }


def fold_benchmark(num_windows: int = 8, events_per_window: int = 2000,
                   repeats: int = 5,
                   modes: tuple = (("batched", True, False),
                                   ("per_window", False, False)),
                   op_name: str = "average",
                   num_keys: int = 256) -> Dict:
    """Fold throughput with ``num_windows`` concurrent due windows:
    batched single-pass execution vs the per-window reference path.
    Reports events folded per second of execution wall time, batch
    occupancy, and device time per window execution.

    ``modes`` rows are ``(label, batched_execution, slot_sharding)`` —
    the ``--devices N`` sweep adds a slot-sharded mode that partitions
    the batch's window slots across the simulated device mesh. The fold
    cost of the keyed operators (``stock``/``lrb``) scales with
    ``num_slots * num_keys`` (the one-hot segment axis), which is the
    regime slot sharding targets: each device reduces a D-times smaller
    row block onto a D-times narrower slot range.
    """
    from repro.configs.base import AionConfig
    from repro.core import StreamEngine, TumblingWindows
    from repro.core.events import EventBatch
    from repro.core.operators import make_operator
    from repro.core.triggers import DeltaTTrigger

    wd = 10.0
    horizon = num_windows * wd
    out: Dict[str, Dict] = {}
    op_kw = {}
    if op_name == "stock":
        op_kw = {"num_keys": num_keys}
    elif op_name == "lrb":
        op_kw = {"num_segments": num_keys}
    for label, batched, sharded in modes:
        aion = AionConfig(block_size=1024, batched_execution=batched,
                          slot_sharding=sharded)
        op = make_operator(op_name, aion.block_size, 1, **op_kw)
        eng = StreamEngine(
            assigner=TumblingWindows(wd), operator=op, aion=aion,
            value_width=1, device_budget_bytes=512 << 20,
            trigger=DeltaTTrigger(executions=1),
        )
        rng = np.random.default_rng(0)
        n = num_windows * events_per_window

        def round_events(r):
            # exactly events_per_window per window: the fold shapes are
            # identical every round, so the numbers reflect steady-state
            # fold throughput rather than one-off jit compiles
            base = r * horizon
            ts = np.concatenate([
                rng.uniform(base + i * wd, base + (i + 1) * wd,
                            events_per_window)
                for i in range(num_windows)])
            return EventBatch(
                rng.integers(0, 64, n).astype(np.int32), ts,
                rng.normal(size=(n, 1)).astype(np.float32))

        # warmup round compiles the fold(s); reset counters so reported
        # device time reflects steady state, not compilation
        eng.ingest(round_events(0), now=0.0)
        eng.advance_watermark(horizon, now=horizon)
        m = eng.metrics
        m.live_executions = 0
        m.batch_executions = 0
        m.batched_windows = 0
        m.sharded_batch_executions = 0
        m.batch_device_seconds = 0.0
        m.batch_occupancy_series.clear()
        times = []
        for r in range(1, repeats + 1):
            eng.ingest(round_events(r), now=r * horizon)
            t0 = time.time()
            # all num_windows windows of this round expire at once
            eng.advance_watermark((r + 1) * horizon, now=(r + 1) * horizon)
            times.append(time.time() - t0)
        eng.io.drain()
        out[label] = {
            "fold_events_per_sec": n * repeats / sum(times),
            "exec_wall_s": round(sum(times), 4),
            "windows_executed": m.live_executions,
            "batch_occupancy": round(m.mean_batch_occupancy, 2),
            "device_s_per_exec": round(m.device_seconds_per_execution, 6),
            "sharded_passes": m.sharded_batch_executions,
        }
        eng.close()
    if "batched" in out and "per_window" in out:
        out["speedup"] = round(
            out["batched"]["fold_events_per_sec"]
            / max(out["per_window"]["fold_events_per_sec"], 1e-9), 2)
    out["num_windows"] = num_windows
    return out


def gather_benchmark(num_windows: int = 8, events_per_window: int = 8000,
                     repeats: int = 20, warmup: int = 3,
                     op_name: str = "lrb", num_keys: int = 64,
                     emit_json: str = "BENCH_q2_gather.json") -> Dict:
    """Gather vs fold seconds for the batched execution path: the
    persistent block pool (block tables, zero-copy) vs the device-concat
    baseline, at ``num_windows`` concurrent due windows.

    Two scenarios:
      * **hot** — everything device-resident (InMemoryPolicy pins blocks,
        so pooled rows never leave the arena between re-executions): the
        pooled gather is a table of Python ints + one take inside the
        fold, the baseline re-stacks every row every batch.
      * **cold** — spill pressure with a simulated persistent tier
        (LocalRhoMinPolicy keeps a rho_min=0.5 bootstrap resident, the
        rest destages after every execution and every re-read pays the
        simulated persistent-tier cost): the pooled path demand-fills
        the cold half at PRIO_DEMAND_STAGE and hides that I/O behind the
        fold of the resident half (stall = what the fold could not
        hide), the baseline pays the same reads synchronously inside the
        gather.

    Reported per mode: gather seconds (batch assembly outside the fold
    call — ``EngineMetrics.batch_gather_seconds``), fold seconds, overlap
    stall, end-to-end fold throughput. The acceptance bar is
    ``hot.gather_speedup >= 3`` at >= 8 due windows; results land in
    ``emit_json`` (checked in as BENCH_q2_gather.json).
    """
    import json

    from repro.configs.base import AionConfig
    from repro.core import InMemoryPolicy, StreamEngine, TumblingWindows
    from repro.core.batch_exec import BatchWorkItem
    from repro.core.events import EventBatch
    from repro.core.operators import make_operator
    from repro.core.policies import LocalRhoMinPolicy
    from repro.core.triggers import DeltaTTrigger

    wd = 10.0
    horizon = num_windows * wd
    n = num_windows * events_per_window
    op_kw = {}
    if op_name == "stock":
        op_kw = {"num_keys": num_keys}
    elif op_name == "lrb":
        op_kw = {"num_segments": num_keys}

    def drive(pooled: bool, hot: bool) -> Dict:
        aion = AionConfig(block_size=1024, batched_execution=True,
                          block_pool=pooled)
        op = make_operator(op_name, aion.block_size, 1, **op_kw)
        eng = StreamEngine(
            assigner=TumblingWindows(wd), operator=op, aion=aion,
            value_width=1, device_budget_bytes=512 << 20,
            # hot: everything stays resident between re-executions;
            # cold: half the blocks destage after every execution
            # (rho_min bootstrap keeps the other half) and persistent-
            # tier reads cost ~0.8 ms/block (simulated)
            policy=InMemoryPolicy() if hot
            else LocalRhoMinPolicy(rho_min=0.5, tau=1e9),
            simulated_seconds_per_byte=0.0 if hot else 5e-8,
            trigger=DeltaTTrigger(executions=1),
        )
        rng = np.random.default_rng(0)
        ts = np.concatenate([
            rng.uniform(i * wd, (i + 1) * wd, events_per_window)
            for i in range(num_windows)])
        eng.ingest(EventBatch(rng.integers(0, num_keys, n).astype(np.int32),
                              ts, rng.normal(size=(n, 1)).astype(np.float32)),
                   now=0.0)
        eng.advance_watermark(horizon, now=horizon)      # live batch+compile
        eng.io.drain()

        def late_batch(r):
            items = [BatchWorkItem(wid, eng.windows[wid], True)
                     for wid in sorted(eng.windows)]
            eng.batch_exec.execute(items, now=horizon + 1.0 + r)
            if not hot:
                eng.io.drain()                  # let destage make it cold
        # warmup rounds compile every fold/gather variant of the late
        # path; reset counters so the measurement is steady state
        for r in range(warmup):
            late_batch(r - warmup)
        m = eng.metrics
        m.batch_gather_seconds = 0.0
        m.batch_device_seconds = 0.0
        m.batch_stall_seconds = 0.0
        m.pooled_rows = m.fallback_rows = m.demand_pool_fills = 0
        # steady state: re-execute the same due set repeatedly (the
        # batched late path — a pure function of bucket contents)
        t0 = time.time()
        for r in range(repeats):
            late_batch(r)
        wall = time.time() - t0
        out = {
            "gather_s": round(m.batch_gather_seconds, 6),
            "fold_s": round(m.batch_device_seconds, 6),
            "stall_s": round(m.batch_stall_seconds, 6),
            "wall_s": round(wall, 6),
            "fold_events_per_sec": round(n * repeats / max(wall, 1e-9)),
            "pooled_rows": m.pooled_rows,
            "fallback_rows": m.fallback_rows,
            "demand_pool_fills": m.demand_pool_fills,
        }
        eng.close()
        return out

    out: Dict = {"num_windows": num_windows,
                 "events_per_window": events_per_window,
                 "repeats": repeats, "workload": op_name}
    for scen, hot in (("hot", True), ("cold", False)):
        pooled = drive(True, hot)
        concat = drive(False, hot)
        out[scen] = {
            "pooled": pooled, "device_concat": concat,
            "gather_speedup": round(
                concat["gather_s"] / max(pooled["gather_s"], 1e-9), 2),
            "throughput_ratio": round(
                pooled["fold_events_per_sec"]
                / max(concat["fold_events_per_sec"], 1e-9), 3),
        }
    if emit_json:
        with open(emit_json, "w") as f:
            json.dump(out, f, indent=2)
    return out


def pipeline_benchmark(num_windows: int = 8, num_rounds: int = 10,
                       events_per_window: int = 4000,
                       sim_spb: float = 8e-7, op_name: str = "lrb",
                       num_keys: int = 256,
                       emit_json: str = "BENCH_q2_gather.json") -> Dict:
    """Pipelined async engine vs the synchronous loop (ISSUE 6
    tentpole): ``num_rounds`` independent groups of ``num_windows`` due
    windows, every p-block cold (destaged to a simulated persistent
    tier), executed end-to-end.

    The synchronous loop pays, per round, demand staging then the fold,
    serially across rounds. The pipelined engine submits every round to
    the fold worker up front: round k+1's staging (prefetch at
    PRIO_STAGE, promoted to PRIO_DEMAND_STAGE when its fold starts)
    overlaps round k's fold, so the end-to-end wall converges to
    max(total I/O, total fold) + one pipeline fill. ``sim_spb`` is tuned
    so staging a round costs about as much as folding it — the regime
    the overlap targets. Acceptance: ``pipeline_vs_sync >= 1.3`` at 8
    due windows; the result merges into ``emit_json``.
    """
    import json
    import os

    from repro.configs.base import AionConfig
    from repro.core import InMemoryPolicy, StreamEngine, TumblingWindows
    from repro.core.batch_exec import BatchWorkItem
    from repro.core.events import EventBatch
    from repro.core.operators import make_operator
    from repro.core.triggers import DeltaTTrigger

    wd = 10.0
    op_kw = {}
    if op_name == "stock":
        op_kw = {"num_keys": num_keys}
    elif op_name == "lrb":
        op_kw = {"num_segments": num_keys}

    def build(pipelined: bool) -> "StreamEngine":
        aion = AionConfig(block_size=1024, batched_execution=True,
                          block_pool=True,
                          pipelined_execution=pipelined)
        op = make_operator(op_name, aion.block_size, 1, **op_kw)
        return StreamEngine(
            assigner=TumblingWindows(wd), operator=op, aion=aion,
            value_width=1, device_budget_bytes=512 << 20,
            policy=InMemoryPolicy(),     # no post-execute destage noise
            simulated_seconds_per_byte=sim_spb,
            trigger=DeltaTTrigger(executions=1),
        )

    def rounds_of(eng):
        """Ingest num_rounds disjoint window groups; returns the groups
        (identical shapes round-over-round: one jit compile)."""
        rng = np.random.default_rng(0)
        n = num_windows * events_per_window
        for r in range(num_rounds):
            base = r * num_windows * wd
            ts = np.concatenate([
                rng.uniform(base + i * wd, base + (i + 1) * wd,
                            events_per_window)
                for i in range(num_windows)])
            eng.ingest(
                EventBatch(rng.integers(0, num_keys, n).astype(np.int32),
                           ts, rng.normal(size=(n, 1)).astype(np.float32)),
                now=0.0)
        wids = sorted(eng.windows)
        assert len(wids) == num_rounds * num_windows
        return [[BatchWorkItem(wid, eng.windows[wid], True)
                 for wid in wids[r * num_windows:(r + 1) * num_windows]]
                for r in range(num_rounds)]

    def make_cold(eng, items):
        for it in items:
            for blk in list(it.state.blocks):
                eng.io.destage_block_sync(blk)

    def drive(pipelined: bool) -> float:
        eng = build(pipelined)
        rounds = rounds_of(eng)
        # warmup: compile the cold-path fold on round 0's group, then
        # re-destage it so the measured run starts fully cold
        make_cold(eng, rounds[0])
        eng.batch_exec.execute(rounds[0], now=1.0)
        for items in rounds:
            make_cold(eng, items)
        assert eng.io.drain(timeout=120)
        t0 = time.time()
        if pipelined:
            for r, items in enumerate(rounds):
                eng._submit_round(items, now=2.0 + r)
            assert eng.pipeline.drain(timeout=300)
        else:
            for r, items in enumerate(rounds):
                eng.batch_exec.execute(items, now=2.0 + r)
        wall = time.time() - t0
        assert eng.observability()["io"]["errors"] == 0
        eng.close()
        return wall

    sync_wall = drive(False)
    pipe_wall = drive(True)
    out = {
        "num_windows": num_windows, "num_rounds": num_rounds,
        "events_per_window": events_per_window, "workload": op_name,
        "sim_seconds_per_byte": sim_spb,
        "sync_wall_s": round(sync_wall, 4),
        "pipelined_wall_s": round(pipe_wall, 4),
        "pipeline_vs_sync": round(sync_wall / max(pipe_wall, 1e-9), 2),
    }
    if emit_json:
        merged = {}
        if os.path.exists(emit_json):
            with open(emit_json) as f:
                merged = json.load(f)
        merged["pipeline"] = out
        with open(emit_json, "w") as f:
            json.dump(merged, f, indent=2)
    return out


def skew_benchmark(num_windows: int = 8, rounds: int = 10,
                   chunk: int = 16, zipf_a: float = 1.4,
                   op_name: str = "stock", num_keys: int = 256,
                   emit_json: str = "BENCH_q2_gather.json") -> Dict:
    """Split-K chunked fold vs the stripe fold on a Zipf-skewed,
    growing-late-table workload (ISSUE 8 tentpole).

    ``num_windows`` due windows whose block tables grow every round —
    late waves dealt across windows by Zipf(``zipf_a``) weights, so one
    hot window owns most rows — then the whole due set re-executes
    (the batched late path). The stripe fold pads the table to the next
    power of two (up to 2x wasted rows) and re-jits every time growth
    crosses a pow2 boundary; the split-K fold decomposes every round
    into {1,2,4,8} x ``chunk``-row launch groups, so after one warmup
    every shape is cached: **zero recompiles as the batch grows** and
    padding bounded by chunk-1 rows.

    Reported per mode: fold seconds, fold row-throughput, recompiles
    during the measured rounds (jit cache-size delta on the operator's
    ``fold_batch``), padded-vs-real row ratio. Acceptance:
    ``splitk_vs_stripe >= 1.5`` at 8 due windows with
    ``recompiles == 0`` on the split-K side; the section merges into
    ``emit_json``."""
    import json
    import os

    from repro.configs.base import AionConfig
    from repro.core import InMemoryPolicy, StreamEngine, TumblingWindows
    from repro.core.batch_exec import BatchWorkItem
    from repro.core.events import EventBatch
    from repro.core.operators import make_operator
    from repro.core.triggers import DeltaTTrigger

    wd = 10.0
    horizon = num_windows * wd
    bs = 256
    # both modes warm at 15*chunk rows (the split-K side needs one round
    # that decomposes 8+4+2+1 to cache every launch shape); measured
    # rounds then grow THROUGH the 256 and 512 pow2 boundaries, so the
    # stripe fold re-jits mid-run and pads up to ~2x, while every
    # split-K decomposition reuses the warmed {1,2,4,8}*chunk shapes
    warm_rows = 15 * chunk
    row_targets = [250, 270, 300, 340, 390, 450, 510, 580, 660, 750,
                   850, 960][:rounds]
    weights = 1.0 / np.arange(1, num_windows + 1) ** zipf_a
    weights /= weights.sum()

    def drive(splitk: int) -> Dict:
        aion = AionConfig(block_size=bs, batched_execution=True,
                          block_pool=True, pool_slots=2048,
                          splitk_chunk_rows=splitk)
        op = make_operator(op_name, bs, 1, num_keys=num_keys)
        eng = StreamEngine(
            assigner=TumblingWindows(wd), operator=op, aion=aion,
            value_width=1, device_budget_bytes=512 << 20,
            policy=InMemoryPolicy(),      # hot arena: fold-bound
            trigger=DeltaTTrigger(executions=1),
        )
        rng = np.random.default_rng(0)

        def grow_to(target_rows: int, have: np.ndarray):
            """Late wave in whole blocks, dealt by Zipf weights."""
            want = np.floor(weights * target_rows).astype(int)
            want[0] += target_rows - want.sum()        # hot window
            delta = np.maximum(want - have, 0)
            parts = []
            for i, d in enumerate(delta):
                if d == 0:
                    continue
                n = d * bs                  # whole blocks: rows == n/bs
                parts.append(EventBatch(
                    rng.integers(0, num_keys, n).astype(np.int32),
                    rng.uniform(i * wd, (i + 1) * wd, n),
                    rng.normal(size=(n, 1)).astype(np.float32)))
            for b in parts:
                eng.ingest(b, now=horizon + 1.0)
            return have + delta

        def late_batch(r):
            items = [BatchWorkItem(wid, eng.windows[wid], True)
                     for wid in sorted(eng.windows)]
            eng.batch_exec.execute(items, now=horizon + 2.0 + r)

        have = np.zeros(num_windows, int)
        have = grow_to(warm_rows, have)
        eng.advance_watermark(horizon, now=horizon)    # live + compile
        eng.io.drain()
        late_batch(-1)                                 # warm the late path
        m = eng.metrics
        cache0 = eng.observability()["fold"]["cache_size"]
        m.batch_device_seconds = 0.0
        m.pooled_rows = 0
        launches0 = m.splitk_launches
        rows_folded = 0
        t0 = time.time()
        for r, target in enumerate(row_targets):
            have = grow_to(max(target, int(have.sum())), have)
            late_batch(r)
            rows_folded += int(have.sum())
        wall = time.time() - t0
        out = {
            "fold_s": round(m.batch_device_seconds, 6),
            "wall_s": round(wall, 6),
            "rows_folded": rows_folded,
            "fold_rows_per_sec": round(
                rows_folded / max(m.batch_device_seconds, 1e-9)),
            "recompiles": eng.observability()["fold"]["cache_size"]
            - cache0,
            "splitk_launches": m.splitk_launches - launches0,
        }
        eng.close()
        return out

    stripe = drive(0)
    splitk_out = drive(chunk)
    out: Dict = {
        "num_windows": num_windows, "rounds": len(row_targets),
        "block_size": bs, "chunk_rows": chunk, "zipf_a": zipf_a,
        "workload": op_name, "num_keys": num_keys,
        "hot_window_share": round(float(weights[0]), 3),
        "stripe": stripe, "splitk": splitk_out,
        "splitk_vs_stripe": round(
            splitk_out["fold_rows_per_sec"]
            / max(stripe["fold_rows_per_sec"], 1e-9), 2),
    }
    if emit_json:
        merged = {}
        if os.path.exists(emit_json):
            with open(emit_json) as f:
                merged = json.load(f)
        merged["splitk_vs_stripe"] = out
        with open(emit_json, "w") as f:
            json.dump(merged, f, indent=2)
    return out


def obs_overhead_benchmark(num_windows: int = 8, rounds: int = 40,
                           events_per_window: int = 4000,
                           op_name: str = "average",
                           emit_json: str = "BENCH_q2_gather.json"
                           ) -> Dict:
    """Tracing-overhead probe (ISSUE 10): the SAME fold-bound late
    re-execution drive at ``trace_sample_rate`` 0.0 vs 1.0.

    Each measured round folds every window's hot (arena-resident) block
    table under a root span — at rate 1.0 every fold-round span, its
    attrs and the ring-buffer append are live; at 0.0 the tracer hands
    out ``NULL_SPAN`` and the instrumented path must cost nothing.
    Acceptance (ISSUE 10): wall overhead at rate 1.0 under 5%. The
    section merges into ``emit_json`` as ``tracing_overhead``."""
    import json
    import os

    from repro.configs.base import AionConfig
    from repro.core import InMemoryPolicy, StreamEngine, TumblingWindows
    from repro.core.batch_exec import BatchWorkItem
    from repro.core.events import EventBatch
    from repro.core.operators import make_operator
    from repro.core.triggers import DeltaTTrigger

    wd = 10.0
    bs = 256
    horizon = num_windows * wd

    def drive(rate: float) -> Dict:
        aion = AionConfig(block_size=bs, batched_execution=True,
                          block_pool=True, pool_slots=2048,
                          trace_sample_rate=rate)
        op = make_operator(op_name, bs, 1)
        eng = StreamEngine(
            assigner=TumblingWindows(wd), operator=op, aion=aion,
            value_width=1, device_budget_bytes=256 << 20,
            policy=InMemoryPolicy(),      # hot arena: fold-bound
            trigger=DeltaTTrigger(executions=1))
        rng = np.random.default_rng(0)
        for i in range(num_windows):
            n = events_per_window
            eng.ingest(EventBatch(
                rng.integers(0, 64, n).astype(np.int32),
                rng.uniform(i * wd, (i + 1) * wd, n),
                rng.normal(size=(n, 1)).astype(np.float32)), now=0.5)
        eng.advance_watermark(horizon, now=horizon)    # live + compile

        def late_items():
            return [BatchWorkItem(wid, eng.windows[wid], True)
                    for wid in sorted(eng.windows)]
        eng.batch_exec.execute(late_items(), now=horizon + 1.0)  # warm
        rows = sum(len(st.blocks) for st in eng.windows.values())
        # min-of-3 timed repetitions: each single loop is tens of ms, so
        # one-shot walls are dominated by host noise, not tracing cost
        wall = float("inf")
        for rep in range(3):
            t0 = time.time()
            for r in range(rounds):
                span = eng.tracer.root("bench_round")
                eng.batch_exec.execute(
                    late_items(), now=horizon + 2.0 + rep * rounds + r,
                    trace_parent=span)
                span.end()
            wall = min(wall, time.time() - t0)
        snap = eng.observability()
        out = {
            "wall_s": round(wall, 6),
            "fold_rows_per_sec": round(rows * rounds / max(wall, 1e-9)),
            "spans_finished": snap["trace"]["spans_finished"],
        }
        eng.close()
        return out

    rate0 = drive(0.0)
    rate1 = drive(1.0)
    overhead = (rate1["wall_s"] - rate0["wall_s"]) \
        / max(rate0["wall_s"], 1e-9) * 100.0
    out: Dict = {
        "num_windows": num_windows, "rounds": rounds,
        "events_per_window": events_per_window, "workload": op_name,
        "rate0": rate0, "rate1": rate1,
        "overhead_pct": round(overhead, 2),
        "pass_lt_5pct": overhead < 5.0,
    }
    if emit_json:
        merged = {}
        if os.path.exists(emit_json):
            with open(emit_json) as f:
                merged = json.load(f)
        merged["tracing_overhead"] = out
        with open(emit_json, "w") as f:
            json.dump(merged, f, indent=2)
    return out


def devices_sweep(num_windows: int = 16, events_per_window: int = 2000,
                  repeats: int = 5, op_name: str = "lrb",
                  num_keys: int = 64) -> Dict:
    """Slot-sharded multi-device fold vs BOTH single-device paths on the
    same workload. Run via ``--devices N`` (the flag forces N simulated
    CPU devices before jax initializes). The acceptance bar: sharded fold
    throughput no worse than single-device. Defaults to the keyed ``lrb``
    workload — the segment-axis-heavy regime the sharding targets: the
    dense one-hot fold costs O(rows * num_slots * num_keys), and each
    device reduces a D-times smaller row block onto a D-times narrower
    slot range (a slot's one-hot columns live on exactly one device), so
    per-device work drops ~D^2 (8 devices, CPU container: ~10x vs the
    unsharded batched fold, and above the per-window path too)."""
    import jax
    out = fold_benchmark(
        num_windows=num_windows, events_per_window=events_per_window,
        repeats=repeats,
        modes=(("batched", True, False), ("sharded", True, True),
               ("per_window", False, False)),
        op_name=op_name, num_keys=num_keys)
    out["num_devices"] = len(jax.devices())
    out["workload"] = op_name
    sharded = out["sharded"]["fold_events_per_sec"]
    out["sharded_vs_single_device"] = round(
        sharded / max(out["batched"]["fold_events_per_sec"], 1e-9), 2)
    out["sharded_vs_per_window"] = round(
        sharded / max(out["per_window"]["fold_events_per_sec"], 1e-9), 2)
    return out


def run(workload_names=("average", "bigrams", "stock_market", "lrb")
        ) -> List[Dict]:
    from repro.configs.workloads import WORKLOADS
    rows = []
    for name in workload_names:
        for include_late in (False, True):
            for baseline in (False, True):
                rows.append(run_one(WORKLOADS[name], baseline, include_late))
    return rows


if __name__ == "__main__":
    import argparse
    import os

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--devices", type=int, default=0, metavar="N",
                    help="simulate N CPU devices and benchmark the "
                         "slot-sharded fold against single-device "
                         "(sets XLA_FLAGS before jax loads)")
    ap.add_argument("--windows", type=int, default=0,
                    help="concurrent due windows (0 = each mode's "
                         "default: 16 for the devices sweep, 8 for "
                         "--gather — the configuration the checked-in "
                         "BENCH_q2_gather.json was measured at)")
    ap.add_argument("--gather", action="store_true",
                    help="run the pooled vs device-concat gather "
                         "benchmark and emit BENCH_q2_gather.json")
    ap.add_argument("--pipeline", action="store_true",
                    help="benchmark the pipelined async engine vs the "
                         "synchronous loop over cold p-blocks and merge "
                         "a pipeline_vs_sync ratio into "
                         "BENCH_q2_gather.json")
    ap.add_argument("--skew", action="store_true",
                    help="benchmark the split-K chunked fold vs the "
                         "stripe fold on a Zipf-skewed growing-late-"
                         "table workload and merge a splitk_vs_stripe "
                         "section into BENCH_q2_gather.json")
    ap.add_argument("--obs", action="store_true",
                    help="measure structured-tracing overhead (sample "
                         "rate 0.0 vs 1.0 on a fold-bound drive) and "
                         "merge a tracing_overhead section into "
                         "BENCH_q2_gather.json")
    args = ap.parse_args()
    if args.devices > 1 and (args.gather or args.pipeline or args.skew
                             or args.obs):
        ap.error("--gather/--pipeline/--skew/--obs measure single-"
                 "device paths; run them without --devices")
    if args.devices > 1:
        flags = os.environ.get("XLA_FLAGS", "")
        os.environ["XLA_FLAGS"] = (
            f"{flags} --xla_force_host_platform_device_count="
            f"{args.devices}").strip()
        print(devices_sweep(num_windows=args.windows or 16))
    elif args.gather:
        import json as _json
        print(_json.dumps(gather_benchmark(
            num_windows=args.windows or 8), indent=2))
    elif args.pipeline:
        import json as _json
        print(_json.dumps(pipeline_benchmark(
            num_windows=args.windows or 8), indent=2))
    elif args.skew:
        import json as _json
        print(_json.dumps(skew_benchmark(
            num_windows=args.windows or 8), indent=2))
    elif args.obs:
        import json as _json
        print(_json.dumps(obs_overhead_benchmark(
            num_windows=args.windows or 8), indent=2))
    else:
        for r in run():
            print(r)
        print(fold_benchmark())
