"""Q2 (paper Figs. 3-5): AION's ingestion/processing-rate overhead vs the
in-memory baseline when everything fits in memory."""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from repro.configs.base import AionConfig
from repro.configs.workloads import WORKLOADS
from repro.core import (
    EngineOOM, InMemoryPolicy, StreamEngine, TumblingWindows,
)
from repro.core.operators import make_operator
from repro.core.triggers import DeltaTTrigger
from repro.data.generators import make_generator

EVENTS_PER_WM = 1500
N_WATERMARKS = 8


def run_one(workload, baseline: bool, include_late: bool) -> Dict:
    gen = make_generator(workload, seed=3)
    aion = AionConfig(block_size=1024)
    kw = {}
    if workload.operator == "stock":
        kw = {"num_keys": workload.num_keys}
    elif workload.operator == "lrb":
        kw = {"num_segments": workload.num_keys}
    elif workload.operator == "bigrams":
        kw = {"vocab": 64}
    op = make_operator(workload.operator, aion.block_size, gen.width, **kw)
    eng = StreamEngine(
        assigner=TumblingWindows(workload.window_duration),
        operator=op, aion=aion, value_width=gen.width,
        device_budget_bytes=512 << 20,       # fits fully in memory (Q2)
        policy=InMemoryPolicy() if baseline else None,
        trigger=DeltaTTrigger(executions=1),
    )
    wd = workload.window_duration
    now = 4 * wd
    ingested = 0
    # warmup
    eng.ingest(gen.batch(200, now), now)
    eng.advance_watermark(now, now)
    t0 = time.time()
    for _ in range(N_WATERMARKS):
        batch = gen.batch(EVENTS_PER_WM, now)
        if not include_late:
            batch = batch.select(batch.timestamps >= now - wd)
        eng.ingest(batch, now)
        ingested += len(batch)
        eng.advance_watermark(now, now)
        eng.poll(now)
        now += wd
    eng.io.drain()
    dt = time.time() - t0
    eng.close()
    return {
        "workload": workload.name,
        "backend": "baseline" if baseline else "aion",
        "late_included": include_late,
        "events_per_sec": ingested / dt,
        "processed_windows": eng.metrics.live_executions
        + eng.metrics.late_executions,
        "fetch_stall_s": round(eng.metrics.fetch_stall_seconds, 4),
    }


def run(workload_names=("average", "bigrams", "stock_market", "lrb")
        ) -> List[Dict]:
    rows = []
    for name in workload_names:
        for include_late in (False, True):
            for baseline in (False, True):
                rows.append(run_one(WORKLOADS[name], baseline, include_late))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
