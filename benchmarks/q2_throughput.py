"""Q2 (paper Figs. 3-5): AION's ingestion/processing-rate overhead vs the
in-memory baseline when everything fits in memory.

Also benchmarks the batched multi-window execution path
(``fold_benchmark``): with many concurrent due windows, folding them in
one device pass vs one ``execute_window`` per window."""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from repro.configs.base import AionConfig
from repro.configs.workloads import WORKLOADS
from repro.core import (
    EngineOOM, InMemoryPolicy, StreamEngine, TumblingWindows,
)
from repro.core.events import EventBatch
from repro.core.operators import make_operator
from repro.core.triggers import DeltaTTrigger
from repro.data.generators import make_generator

EVENTS_PER_WM = 1500
N_WATERMARKS = 8


def run_one(workload, baseline: bool, include_late: bool) -> Dict:
    gen = make_generator(workload, seed=3)
    aion = AionConfig(block_size=1024)
    kw = {}
    if workload.operator == "stock":
        kw = {"num_keys": workload.num_keys}
    elif workload.operator == "lrb":
        kw = {"num_segments": workload.num_keys}
    elif workload.operator == "bigrams":
        kw = {"vocab": 64}
    op = make_operator(workload.operator, aion.block_size, gen.width, **kw)
    eng = StreamEngine(
        assigner=TumblingWindows(workload.window_duration),
        operator=op, aion=aion, value_width=gen.width,
        device_budget_bytes=512 << 20,       # fits fully in memory (Q2)
        policy=InMemoryPolicy() if baseline else None,
        trigger=DeltaTTrigger(executions=1),
    )
    wd = workload.window_duration
    now = 4 * wd
    ingested = 0
    # warmup
    eng.ingest(gen.batch(200, now), now)
    eng.advance_watermark(now, now)
    t0 = time.time()
    for _ in range(N_WATERMARKS):
        batch = gen.batch(EVENTS_PER_WM, now)
        if not include_late:
            batch = batch.select(batch.timestamps >= now - wd)
        eng.ingest(batch, now)
        ingested += len(batch)
        eng.advance_watermark(now, now)
        eng.poll(now)
        now += wd
    eng.io.drain()
    dt = time.time() - t0
    eng.close()
    return {
        "workload": workload.name,
        "backend": "baseline" if baseline else "aion",
        "late_included": include_late,
        "events_per_sec": ingested / dt,
        "processed_windows": eng.metrics.live_executions
        + eng.metrics.late_executions,
        "fetch_stall_s": round(eng.metrics.fetch_stall_seconds, 4),
        "batch_occupancy": round(eng.metrics.mean_batch_occupancy, 2),
        "device_s_per_exec": round(
            eng.metrics.device_seconds_per_execution, 6),
    }


def fold_benchmark(num_windows: int = 8, events_per_window: int = 2000,
                   repeats: int = 5) -> Dict:
    """Fold throughput with ``num_windows`` concurrent due windows:
    batched single-pass execution vs the per-window reference path on the
    ``average`` workload. Reports events folded per second of execution
    wall time, batch occupancy, and device time per window execution."""
    wd = 10.0
    horizon = num_windows * wd
    out: Dict[str, Dict] = {}
    for batched in (True, False):
        aion = AionConfig(block_size=1024, batched_execution=batched)
        op = make_operator("average", aion.block_size, 1)
        eng = StreamEngine(
            assigner=TumblingWindows(wd), operator=op, aion=aion,
            value_width=1, device_budget_bytes=512 << 20,
            trigger=DeltaTTrigger(executions=1),
        )
        rng = np.random.default_rng(0)
        n = num_windows * events_per_window

        def round_events(r):
            # exactly events_per_window per window: the fold shapes are
            # identical every round, so the numbers reflect steady-state
            # fold throughput rather than one-off jit compiles
            base = r * horizon
            ts = np.concatenate([
                rng.uniform(base + i * wd, base + (i + 1) * wd,
                            events_per_window)
                for i in range(num_windows)])
            return EventBatch(
                rng.integers(0, 64, n).astype(np.int32), ts,
                rng.normal(size=(n, 1)).astype(np.float32))

        # warmup round compiles the fold(s); reset counters so reported
        # device time reflects steady state, not compilation
        eng.ingest(round_events(0), now=0.0)
        eng.advance_watermark(horizon, now=horizon)
        m = eng.metrics
        m.live_executions = 0
        m.batch_executions = 0
        m.batched_windows = 0
        m.batch_device_seconds = 0.0
        m.batch_occupancy_series.clear()
        times = []
        for r in range(1, repeats + 1):
            eng.ingest(round_events(r), now=r * horizon)
            t0 = time.time()
            # all num_windows windows of this round expire at once
            eng.advance_watermark((r + 1) * horizon, now=(r + 1) * horizon)
            times.append(time.time() - t0)
        eng.io.drain()
        out["batched" if batched else "per_window"] = {
            "fold_events_per_sec": n * repeats / sum(times),
            "exec_wall_s": round(sum(times), 4),
            "windows_executed": m.live_executions,
            "batch_occupancy": round(m.mean_batch_occupancy, 2),
            "device_s_per_exec": round(m.device_seconds_per_execution, 6),
        }
        eng.close()
    out["speedup"] = round(
        out["batched"]["fold_events_per_sec"]
        / max(out["per_window"]["fold_events_per_sec"], 1e-9), 2)
    out["num_windows"] = num_windows
    return out


def run(workload_names=("average", "bigrams", "stock_market", "lrb")
        ) -> List[Dict]:
    rows = []
    for name in workload_names:
        for include_late in (False, True):
            for baseline in (False, True):
                rows.append(run_one(WORKLOADS[name], baseline, include_late))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
    print(fold_benchmark())
