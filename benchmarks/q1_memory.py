"""Q1 (paper Fig. 2): does AION bound memory under growing lateness?

For each workload, run AION vs the in-memory baseline with the number of
past windows (max allowed lateness) growing; record the device-tier bytes
and whether the baseline OOMs. Scales are reduced (virtual time, small
budget) so the benchmark finishes in seconds — the *shape* of the result
(AION flat, baseline linear until crash) is the reproduction target.

``storage_pressure_run`` adds the persistent-tier half: the same spill
pressure driven through the log-structured store vs the legacy npz
backend — storage bytes written/read/compacted, write amplification, and
the batched p-bucket fetch latency of each. ``python benchmarks/
q1_memory.py`` emits the whole thing machine-readable as
``BENCH_q1_memory.json`` (the q2-gather convention).
"""
from __future__ import annotations

import json
import time
from typing import Dict, List

import numpy as np

from repro.configs.base import AionConfig
from repro.configs.workloads import WORKLOADS
from repro.core import (
    EngineOOM, InMemoryPolicy, PeriodicWatermarkGenerator, StreamEngine,
    TumblingWindows,
)
from repro.core.operators import make_operator
from repro.core.triggers import DeltaTTrigger
from repro.data.generators import make_generator

BUDGET = 24 << 20
EVENTS_PER_WM = 2500
N_WATERMARKS = 10


def _build(workload, baseline: bool, seed=0):
    gen = make_generator(workload, seed=seed)
    aion = AionConfig(block_size=256)
    kw = {}
    if workload.operator == "stock":
        kw = {"num_keys": workload.num_keys}
    elif workload.operator == "lrb":
        kw = {"num_segments": workload.num_keys}
    elif workload.operator == "bigrams":
        kw = {"vocab": 64}
    op = make_operator(workload.operator, aion.block_size, gen.width, **kw)
    eng = StreamEngine(
        assigner=TumblingWindows(workload.window_duration),
        operator=op, aion=aion, value_width=gen.width,
        device_budget_bytes=BUDGET,
        policy=InMemoryPolicy() if baseline else None,
        trigger=DeltaTTrigger(executions=2),
    )
    return gen, eng


def run_one(workload, baseline: bool, past_windows: int) -> Dict:
    gen, eng = _build(workload, baseline)
    wd = workload.window_duration
    now = past_windows * wd
    rng = np.random.default_rng(1)
    device_samples: List[int] = []
    oom_at = None
    t0 = time.time()
    try:
        for wm_i in range(N_WATERMARKS):
            batch = gen.batch(EVENTS_PER_WM, now)
            # clamp lateness to the experiment's past-window horizon
            batch.timestamps = np.maximum(batch.timestamps,
                                          now - past_windows * wd)
            eng.ingest(batch, now)
            device_samples.append(eng.device_bytes())   # while window live
            eng.advance_watermark(now, now)
            device_samples.append(eng.device_bytes())
            eng.poll(now)
            now += wd
    except EngineOOM:
        oom_at = wm_i
    eng.close()
    return {
        "workload": workload.name,
        "backend": "baseline" if baseline else "aion",
        "past_windows": past_windows,
        "median_device_mb": float(np.median(device_samples)) / 2**20
        if device_samples else float("nan"),
        "peak_device_mb": eng.budget.peak_bytes / 2**20,
        "oom_at_watermark": oom_at,
        "seconds": time.time() - t0,
    }


def run(workload_names=("average", "stock_market"),
        past_windows=(1, 4, 8)) -> List[Dict]:
    rows = []
    for name in workload_names:
        w = WORKLOADS[name]
        for pw in past_windows:
            for baseline in (False, True):
                rows.append(run_one(w, baseline, pw))
    return rows


# --------------------------------------------------------- storage tier
def _storage_drive(backend: str, spill_dir, events: int = 16_000,
                   fetch_rounds: int = 5,
                   prefetch: str = "fixed", **aion_extra) -> Dict:
    """Drive one backend through sustained spill pressure + purges, then
    time the batched p-bucket fetch path (``store.get_many`` over the
    spilled working set)."""
    from repro.core.cleanup import PredictiveCleanup

    aion_extra.setdefault("block_size", 256)
    aion_extra.setdefault("store_segment_bytes", 32 << 10)
    aion = AionConfig(store_backend=backend,
                      prefetch_backend=prefetch, **aion_extra)
    eng = StreamEngine(
        assigner=TumblingWindows(10.0),
        operator=make_operator("average", aion.block_size, 1),
        aion=aion, value_width=1,
        # tiny budgets: blocks continuously destage AND spill
        device_budget_bytes=1 << 20,
        host_budget_bytes=32 << 10,
        spill_dir=spill_dir,
        # a short purge bound: predictive cleanup purges most expired
        # windows during the run, so tombstone-driven compaction shows
        # up in the storage counters
        cleanup=PredictiveCleanup(initial_bound=12.0,
                                  min_history=1 << 62),
        trigger=DeltaTTrigger(executions=2),
    )
    rng = np.random.default_rng(7)
    now, wm, emitted = 0.0, 0.0, 0
    t0 = time.time()
    while emitted < events:
        n = min(500, events - emitted)
        delay = np.where(rng.random(n) < 0.6,
                         rng.uniform(0.0, 2.0, n),
                         rng.uniform(0.0, 25.0, n))
        ts = np.maximum(now - delay, 0.0)
        from repro.core.events import EventBatch
        eng.ingest(EventBatch(rng.integers(0, 8, n), ts,
                              rng.normal(size=(n, 1)).astype(np.float32)),
                   now)
        emitted += n
        wm = max(wm, now - 2.0)
        eng.advance_watermark(wm, now)
        eng.poll(now)
        now += rng.uniform(1.0, 3.0)
    eng.io.drain()
    ingest_wall = time.time() - t0

    store = eng.io.store
    # batched fetch latency over the spilled working set (the batched
    # p-bucket read path the gather uses)
    spilled = [(b.window_key, b.block_id)
               for st in eng.windows.values() for b in st.blocks
               if b.tier.value == "storage"]
    fetch_per_block = float("nan")
    if spilled:
        # cold timing: bypass the readahead cache by clearing it first
        per_round = []
        for _ in range(fetch_rounds):
            if hasattr(store, "_cache"):
                store._cache.clear()
                store._cache_bytes = 0
            f0 = time.time()
            got = store.get_many(spilled)
            per_round.append((time.time() - f0) / max(len(spilled), 1))
            assert all(g is not None for g in got)
        fetch_per_block = float(np.median(per_round))
    obs = eng.observability()
    store_stats = obs["store"]
    out = {
        "backend": backend,
        "prefetch": prefetch,
        "events": events,
        "ingest_wall_s": round(ingest_wall, 4),
        "purged_windows": obs["engine"]["purged_windows"],
        "spilled_blocks": len(spilled),
        "bytes_written": int(store_stats["bytes_written"]),
        "bytes_read": int(store_stats["bytes_read"]),
        "bytes_compacted": int(store_stats["bytes_compacted"]),
        "logical_bytes_written": int(
            store_stats["logical_bytes_written"]),
        "write_amplification": round(store.write_amplification, 4),
        "on_disk_bytes": int(store.on_disk_bytes()),
        "live_bytes": int(store.live_bytes()),
        "batched_fetch_s_per_block": fetch_per_block,
        "group_commits": int(store_stats["commits"]),
        "coalesced_windows": int(store_stats.get("coalesced_windows", 0)),
        "coalesce_bytes": int(store_stats.get("coalesce_bytes", 0)),
        "segment_sweeps": int(store_stats.get("segment_sweeps", 0)),
    }
    eng.close()
    return out


def storage_pressure_run(spill_root=None) -> Dict:
    """Log vs npz persistent tier under identical spill pressure.

    Headline: the log store sustains the same pressure with batched
    group-committed writes and a batched-read fetch latency no worse
    than 1.5x the file-per-block baseline (acceptance bar)."""
    import tempfile
    root = spill_root or tempfile.mkdtemp(prefix="q1_storage_")
    from pathlib import Path
    root = Path(root)
    out: Dict = {}
    for backend in ("log", "npz"):
        out[backend] = _storage_drive(backend, root / backend)
    lf = out["log"]["batched_fetch_s_per_block"]
    nf = out["npz"]["batched_fetch_s_per_block"]
    out["fetch_latency_ratio_log_vs_npz"] = round(lf / max(nf, 1e-12), 4)
    out["acceptance_fetch_ratio_max"] = 1.5
    return out


def coalescing_run(spill_root=None) -> Dict:
    """The log store under the learned prefetch backend: coalescing
    rewrites (scattered hot windows -> one dense run) and WAL-coalesced
    group commits are bounded-overhead — total write amplification must
    stay <= 1.1 (acceptance bar) while readahead turns segment-granular.
    """
    import tempfile
    from pathlib import Path
    root = Path(spill_root or tempfile.mkdtemp(prefix="q1_coalesce_"))
    out: Dict = {}
    for prefetch in ("fixed", "learned"):
        # larger segments than the compaction-focused storage run: a hot
        # window's records must fit one segment for a dense rewrite to
        # be profitable (the store-side guard skips it otherwise)
        out[prefetch] = _storage_drive(
            "log", root / prefetch, prefetch=prefetch,
            store_segment_bytes=256 << 10,
            prefetch_coalesce_probability=0.1)
    out["write_amplification_with_coalescing"] = \
        out["learned"]["write_amplification"]
    out["acceptance_write_amplification_max"] = 1.1
    # the engine drives above spill each window's blocks contiguously
    # (group commit), so coalescing correctly no-ops there; the layout
    # demo below interleaves windows on purpose to measure the rewrite
    # itself: scatter before/after and the write-amp it costs
    out["layout_rewrite"] = layout_rewrite_demo(root / "rewrite")
    return out


def layout_rewrite_demo(path, windows: int = 4, rounds: int = 6) -> Dict:
    """Interleave several windows' block writes (worst-case scatter),
    coalesce them, and report the dense layout + total write
    amplification including the rewrite bytes."""
    from repro.storage import LogBlockStore

    rng = np.random.default_rng(3)
    store = LogBlockStore(path, segment_bytes=1 << 20)
    wks = [(i * 10.0, (i + 1) * 10.0) for i in range(windows)]
    bid = 0
    for _ in range(rounds):
        for wk in wks:                       # round-robin: scattered
            arrays = {
                "keys": rng.integers(0, 99, 256).astype(np.int32),
                "timestamps": rng.uniform(0, 100, 256),
                "values": rng.normal(size=(256, 1)).astype(np.float32),
            }
            store.put(wk, bid, arrays, 256)
            bid += 1
        store.commit()
    before = {f"{wk}": store.window_scatter(wk) for wk in wks}
    rewritten = store.coalesce_windows(wks)
    after = {f"{wk}": store.window_scatter(wk) for wk in wks}
    out = {
        "windows": windows,
        "records_per_window": rounds,
        "rewritten_windows": int(rewritten),
        "span_over_record_bytes_before": round(float(np.mean(
            [s[2] / max(s[3], 1) for s in before.values()])), 3),
        "span_over_record_bytes_after": round(float(np.mean(
            [s[2] / max(s[3], 1) for s in after.values()])), 3),
        "coalesce_bytes": int(store.stats["coalesce_bytes"]),
        "write_amplification": round(store.write_amplification, 4),
    }
    store.close()
    return out


def main(emit_json: str = "BENCH_q1_memory.json") -> Dict:
    out = {"memory_rows": run(), "storage": storage_pressure_run(),
           "coalescing": coalescing_run()}
    if emit_json:
        with open(emit_json, "w") as f:
            json.dump(out, f, indent=2)
    return out


if __name__ == "__main__":
    result = main()
    for r in result["memory_rows"]:
        print(r)
    print(json.dumps(result["storage"], indent=2))
