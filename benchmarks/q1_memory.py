"""Q1 (paper Fig. 2): does AION bound memory under growing lateness?

For each workload, run AION vs the in-memory baseline with the number of
past windows (max allowed lateness) growing; record the device-tier bytes
and whether the baseline OOMs. Scales are reduced (virtual time, small
budget) so the benchmark finishes in seconds — the *shape* of the result
(AION flat, baseline linear until crash) is the reproduction target.
"""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from repro.configs.base import AionConfig
from repro.configs.workloads import WORKLOADS
from repro.core import (
    EngineOOM, InMemoryPolicy, PeriodicWatermarkGenerator, StreamEngine,
    TumblingWindows,
)
from repro.core.operators import make_operator
from repro.core.triggers import DeltaTTrigger
from repro.data.generators import make_generator

BUDGET = 24 << 20
EVENTS_PER_WM = 2500
N_WATERMARKS = 10


def _build(workload, baseline: bool, seed=0):
    gen = make_generator(workload, seed=seed)
    aion = AionConfig(block_size=256)
    kw = {}
    if workload.operator == "stock":
        kw = {"num_keys": workload.num_keys}
    elif workload.operator == "lrb":
        kw = {"num_segments": workload.num_keys}
    elif workload.operator == "bigrams":
        kw = {"vocab": 64}
    op = make_operator(workload.operator, aion.block_size, gen.width, **kw)
    eng = StreamEngine(
        assigner=TumblingWindows(workload.window_duration),
        operator=op, aion=aion, value_width=gen.width,
        device_budget_bytes=BUDGET,
        policy=InMemoryPolicy() if baseline else None,
        trigger=DeltaTTrigger(executions=2),
    )
    return gen, eng


def run_one(workload, baseline: bool, past_windows: int) -> Dict:
    gen, eng = _build(workload, baseline)
    wd = workload.window_duration
    now = past_windows * wd
    rng = np.random.default_rng(1)
    device_samples: List[int] = []
    oom_at = None
    t0 = time.time()
    try:
        for wm_i in range(N_WATERMARKS):
            batch = gen.batch(EVENTS_PER_WM, now)
            # clamp lateness to the experiment's past-window horizon
            batch.timestamps = np.maximum(batch.timestamps,
                                          now - past_windows * wd)
            eng.ingest(batch, now)
            device_samples.append(eng.device_bytes())   # while window live
            eng.advance_watermark(now, now)
            device_samples.append(eng.device_bytes())
            eng.poll(now)
            now += wd
    except EngineOOM:
        oom_at = wm_i
    eng.close()
    return {
        "workload": workload.name,
        "backend": "baseline" if baseline else "aion",
        "past_windows": past_windows,
        "median_device_mb": float(np.median(device_samples)) / 2**20
        if device_samples else float("nan"),
        "peak_device_mb": eng.budget.peak_bytes / 2**20,
        "oom_at_watermark": oom_at,
        "seconds": time.time() - t0,
    }


def run(workload_names=("average", "stock_market"),
        past_windows=(1, 4, 8)) -> List[Dict]:
    rows = []
    for name in workload_names:
        w = WORKLOADS[name]
        for pw in past_windows:
            for baseline in (False, True):
                rows.append(run_one(w, baseline, pw))
    return rows


if __name__ == "__main__":
    for r in run():
        print(r)
