"""Q4 (paper Fig. 9): staleness-minimizing trigger vs deltat/deltaev.

Left: max staleness vs number of executions under log-normal lateness.
Right: minimum executions to reach bounds {0.1, 0.05, 0.01} across the
four lateness distributions {lnorm, unif, norm, bursts}.

``store_probe`` adds the engine-in-the-loop half: late re-executions
whose state comes back through the persistent tier, per store backend —
staleness is bounded by how fast the p-bucket serves the re-read, so the
probe reports the storage bytes moved alongside the execution counts.
``python benchmarks/q4_staleness.py`` emits everything machine-readable
as ``BENCH_q4_staleness.json`` (the q2-gather convention).
"""
from __future__ import annotations

import json
from typing import Dict, List

import numpy as np

from repro.core.staleness import (
    deltaev_times, deltat_times, executions_for_bound, max_staleness_of,
    minimize_max_staleness,
)
from repro.data.generators import lateness_delays

T = 100.0
N = 20000


def staleness_vs_executions(dist: str = "lnorm",
                            ks=(2, 4, 8, 16, 20)) -> List[Dict]:
    rng = np.random.default_rng(0)
    delays = lateness_delays(dist, N, T, rng)
    rows = []
    for k in ks:
        rows.append({
            "dist": dist, "k": k,
            "aion": minimize_max_staleness(delays, T, k).max_staleness,
            "deltat": max_staleness_of(deltat_times(T, k), delays, T),
            "deltaev": max_staleness_of(deltaev_times(delays, T, k),
                                        delays, T),
        })
    return rows


def executions_for_bounds(bounds=(0.1, 0.05, 0.01),
                          dists=("lnorm", "unif", "norm", "bursts"),
                          k_max: int = 40) -> List[Dict]:
    rng = np.random.default_rng(1)
    rows = []
    for dist in dists:
        delays = lateness_delays(dist, N, T, rng)
        for bound in bounds:
            rows.append({
                "dist": dist, "bound": bound,
                "aion": executions_for_bound(
                    lambda k: minimize_max_staleness(delays, T, k).times,
                    delays, T, bound, k_max),
                "deltat": executions_for_bound(
                    lambda k: deltat_times(T, k), delays, T, bound, k_max),
                "deltaev": executions_for_bound(
                    lambda k: deltaev_times(delays, T, k), delays, T, bound,
                    k_max),
            })
    return rows


def store_probe(events: int = 10_000) -> List[Dict]:
    """Late re-executions with p-bucket state behind each store backend:
    execution counts, stall seconds, and the storage-tier bytes that
    served the re-reads (staleness is bounded by that fetch path)."""
    import tempfile
    import time
    from pathlib import Path

    from repro.configs.base import AionConfig
    from repro.core import StreamEngine, TumblingWindows
    from repro.core.cleanup import PredictiveCleanup
    from repro.core.events import EventBatch
    from repro.core.operators import make_operator
    from repro.core.triggers import DeltaTTrigger

    root = Path(tempfile.mkdtemp(prefix="q4_store_"))
    rows = []
    for backend in ("log", "npz"):
        aion = AionConfig(block_size=256, store_backend=backend,
                          store_segment_bytes=256 << 10)
        eng = StreamEngine(
            assigner=TumblingWindows(10.0),
            operator=make_operator("average", aion.block_size, 1),
            aion=aion, value_width=1,
            device_budget_bytes=1 << 20, host_budget_bytes=32 << 10,
            spill_dir=root / backend,
            cleanup=PredictiveCleanup(initial_bound=50.0,
                                      min_history=1 << 62),
            trigger=DeltaTTrigger(executions=3),
        )
        rng = np.random.default_rng(5)
        now, emitted = 0.0, 0
        t0 = time.time()
        while emitted < events:
            n = min(500, events - emitted)
            delay = np.where(rng.random(n) < 0.5,
                             rng.uniform(0.0, 2.0, n),
                             rng.uniform(0.0, 30.0, n))
            ts = np.maximum(now - delay, 0.0)
            eng.ingest(
                EventBatch(rng.integers(0, 8, n), ts,
                           rng.normal(size=(n, 1)).astype(np.float32)),
                now)
            emitted += n
            eng.advance_watermark(max(now - 2.0, 0.0), now)
            eng.poll(now)
            now += rng.uniform(1.0, 3.0)
        for t in np.linspace(now, now + 60.0, 10):
            eng.poll(t)
        eng.io.drain()
        store = eng.io.store
        rows.append({
            "backend": backend,
            "events": events,
            "wall_s": round(time.time() - t0, 4),
            "late_executions": eng.metrics.late_executions,
            "live_executions": eng.metrics.live_executions,
            "fetch_stall_s": round(eng.metrics.fetch_stall_seconds, 6),
            "store_bytes_written": int(store.stats["bytes_written"]),
            "store_bytes_read": int(store.stats["bytes_read"]),
            "store_bytes_compacted": int(store.stats["bytes_compacted"]),
            "write_amplification": round(store.write_amplification, 4),
            "readahead_hits": int(store.stats["readahead_hits"]),
            "readahead_misses": int(store.stats["readahead_misses"]),
        })
        eng.close()
    return rows


def run() -> Dict[str, List[Dict]]:
    return {
        "staleness_vs_executions": staleness_vs_executions(),
        "executions_for_bounds": executions_for_bounds(),
    }


def main(emit_json: str = "BENCH_q4_staleness.json") -> Dict:
    out = run()
    out["store_probe"] = store_probe()
    if emit_json:
        with open(emit_json, "w") as f:
            json.dump(out, f, indent=2)
    return out


if __name__ == "__main__":
    out = main()
    for section, rows in out.items():
        print(f"== {section}")
        for r in rows:
            print(r)
