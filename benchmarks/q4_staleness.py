"""Q4 (paper Fig. 9): staleness-minimizing trigger vs deltat/deltaev.

Left: max staleness vs number of executions under log-normal lateness.
Right: minimum executions to reach bounds {0.1, 0.05, 0.01} across the
four lateness distributions {lnorm, unif, norm, bursts}.
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from repro.core.staleness import (
    deltaev_times, deltat_times, executions_for_bound, max_staleness_of,
    minimize_max_staleness,
)
from repro.data.generators import lateness_delays

T = 100.0
N = 20000


def staleness_vs_executions(dist: str = "lnorm",
                            ks=(2, 4, 8, 16, 20)) -> List[Dict]:
    rng = np.random.default_rng(0)
    delays = lateness_delays(dist, N, T, rng)
    rows = []
    for k in ks:
        rows.append({
            "dist": dist, "k": k,
            "aion": minimize_max_staleness(delays, T, k).max_staleness,
            "deltat": max_staleness_of(deltat_times(T, k), delays, T),
            "deltaev": max_staleness_of(deltaev_times(delays, T, k),
                                        delays, T),
        })
    return rows


def executions_for_bounds(bounds=(0.1, 0.05, 0.01),
                          dists=("lnorm", "unif", "norm", "bursts"),
                          k_max: int = 40) -> List[Dict]:
    rng = np.random.default_rng(1)
    rows = []
    for dist in dists:
        delays = lateness_delays(dist, N, T, rng)
        for bound in bounds:
            rows.append({
                "dist": dist, "bound": bound,
                "aion": executions_for_bound(
                    lambda k: minimize_max_staleness(delays, T, k).times,
                    delays, T, bound, k_max),
                "deltat": executions_for_bound(
                    lambda k: deltat_times(T, k), delays, T, bound, k_max),
                "deltaev": executions_for_bound(
                    lambda k: deltaev_times(delays, T, k), delays, T, bound,
                    k_max),
            })
    return rows


def run() -> Dict[str, List[Dict]]:
    return {
        "staleness_vs_executions": staleness_vs_executions(),
        "executions_for_bounds": executions_for_bounds(),
    }


if __name__ == "__main__":
    out = run()
    for section, rows in out.items():
        print(f"== {section}")
        for r in rows:
            print(r)
