"""Q4 (paper Fig. 9): staleness-minimizing trigger vs deltat/deltaev.

Left: max staleness vs number of executions under log-normal lateness.
Right: minimum executions to reach bounds {0.1, 0.05, 0.01} across the
four lateness distributions {lnorm, unif, norm, bursts}.

``store_probe`` adds the engine-in-the-loop half: late re-executions
whose state comes back through the persistent tier, per store backend —
staleness is bounded by how fast the p-bucket serves the re-read, so the
probe reports the storage bytes moved alongside the execution counts.
``python benchmarks/q4_staleness.py`` emits everything machine-readable
as ``BENCH_q4_staleness.json`` (the q2-gather convention).
"""
from __future__ import annotations

import json
from typing import Dict, List

import numpy as np

from repro.core.staleness import (
    deltaev_times, deltat_times, executions_for_bound, max_staleness_of,
    minimize_max_staleness,
)
from repro.data.generators import lateness_delays

T = 100.0
N = 20000


def staleness_vs_executions(dist: str = "lnorm",
                            ks=(2, 4, 8, 16, 20)) -> List[Dict]:
    rng = np.random.default_rng(0)
    delays = lateness_delays(dist, N, T, rng)
    rows = []
    for k in ks:
        rows.append({
            "dist": dist, "k": k,
            "aion": minimize_max_staleness(delays, T, k).max_staleness,
            "deltat": max_staleness_of(deltat_times(T, k), delays, T),
            "deltaev": max_staleness_of(deltaev_times(delays, T, k),
                                        delays, T),
        })
    return rows


def executions_for_bounds(bounds=(0.1, 0.05, 0.01),
                          dists=("lnorm", "unif", "norm", "bursts"),
                          k_max: int = 40) -> List[Dict]:
    rng = np.random.default_rng(1)
    rows = []
    for dist in dists:
        delays = lateness_delays(dist, N, T, rng)
        for bound in bounds:
            rows.append({
                "dist": dist, "bound": bound,
                "aion": executions_for_bound(
                    lambda k: minimize_max_staleness(delays, T, k).times,
                    delays, T, bound, k_max),
                "deltat": executions_for_bound(
                    lambda k: deltat_times(T, k), delays, T, bound, k_max),
                "deltaev": executions_for_bound(
                    lambda k: deltaev_times(delays, T, k), delays, T, bound,
                    k_max),
            })
    return rows


def store_probe(events: int = 10_000) -> List[Dict]:
    """Late re-executions with p-bucket state behind each store backend:
    execution counts, stall seconds, and the storage-tier bytes that
    served the re-reads (staleness is bounded by that fetch path)."""
    import tempfile
    import time
    from pathlib import Path

    from repro.configs.base import AionConfig
    from repro.core import StreamEngine, TumblingWindows
    from repro.core.cleanup import PredictiveCleanup
    from repro.core.events import EventBatch
    from repro.core.operators import make_operator
    from repro.core.triggers import DeltaTTrigger

    root = Path(tempfile.mkdtemp(prefix="q4_store_"))
    rows = []
    for backend in ("log", "npz"):
        aion = AionConfig(block_size=256, store_backend=backend,
                          store_segment_bytes=256 << 10)
        eng = StreamEngine(
            assigner=TumblingWindows(10.0),
            operator=make_operator("average", aion.block_size, 1),
            aion=aion, value_width=1,
            device_budget_bytes=1 << 20, host_budget_bytes=32 << 10,
            spill_dir=root / backend,
            cleanup=PredictiveCleanup(initial_bound=50.0,
                                      min_history=1 << 62),
            trigger=DeltaTTrigger(executions=3),
        )
        rng = np.random.default_rng(5)
        now, emitted = 0.0, 0
        t0 = time.time()
        while emitted < events:
            n = min(500, events - emitted)
            delay = np.where(rng.random(n) < 0.5,
                             rng.uniform(0.0, 2.0, n),
                             rng.uniform(0.0, 30.0, n))
            ts = np.maximum(now - delay, 0.0)
            eng.ingest(
                EventBatch(rng.integers(0, 8, n), ts,
                           rng.normal(size=(n, 1)).astype(np.float32)),
                now)
            emitted += n
            eng.advance_watermark(max(now - 2.0, 0.0), now)
            eng.poll(now)
            now += rng.uniform(1.0, 3.0)
        for t in np.linspace(now, now + 60.0, 10):
            eng.poll(t)
        eng.io.drain()
        store = eng.io.store
        obs = eng.observability()
        rows.append({
            "backend": backend,
            "events": events,
            "wall_s": round(time.time() - t0, 4),
            "late_executions": obs["engine"]["late_executions"],
            "live_executions": obs["engine"]["live_executions"],
            "fetch_stall_s": round(
                obs["engine"]["fetch_stall_seconds"], 6),
            "store_bytes_written": int(obs["store"]["bytes_written"]),
            "store_bytes_read": int(obs["store"]["bytes_read"]),
            "store_bytes_compacted": int(
                obs["store"]["bytes_compacted"]),
            "write_amplification": round(store.write_amplification, 4),
            "readahead_hits": int(obs["store"]["readahead_hits"]),
            "readahead_misses": int(obs["store"]["readahead_misses"]),
        })
        eng.close()
    return rows


def _prefetch_run(backend: str, events: int, root) -> Dict:
    import time

    from repro.configs.base import AionConfig
    from repro.core import StreamEngine, TumblingWindows
    from repro.core.cleanup import PredictiveCleanup
    from repro.core.events import EventBatch
    from repro.core.operators import make_operator
    from repro.core.triggers import DeltaTTrigger

    aion = AionConfig(block_size=64, store_backend="log",
                      store_segment_bytes=64 << 10,
                      prefetch_backend=backend)
    eng = StreamEngine(
        assigner=TumblingWindows(10.0),
        operator=make_operator("average", aion.block_size, 1),
        aion=aion, value_width=1,
        # equal memory for both backends: tiny host tier forces the
        # p-buckets through storage, so readahead is load-bearing
        device_budget_bytes=1 << 19, host_budget_bytes=1 << 15,
        spill_dir=root,
        cleanup=PredictiveCleanup(initial_bound=80.0,
                                  min_history=1 << 62),
        trigger=DeltaTTrigger(executions=3),
    )
    rng = np.random.default_rng(11)
    now, emitted = 0.0, 0
    t0 = time.time()
    while emitted < events:
        n = min(250, events - emitted)
        late = rng.random(n) < 0.45
        delay = np.where(late, rng.lognormal(0.0, 1.0, n) * 8.0,
                         rng.uniform(0.0, 1.5, n))
        ts = np.maximum(now - delay, 0.0)
        eng.ingest(
            EventBatch(rng.integers(0, 64, n), ts,
                       np.ones((n, 1), np.float32)), now)
        emitted += n
        eng.advance_watermark(max(now - 2.0, 0.0), now)
        eng.poll(now)
        now += rng.uniform(0.2, 0.5)
    for t in np.linspace(now, now + 80.0, 12):
        eng.poll(t)
    eng.io.drain()
    store = eng.io.store
    obs = eng.observability()
    hits = int(obs["store"]["readahead_hits"])
    misses = int(obs["store"]["readahead_misses"])
    row = {
        "prefetch": backend,
        "events": events,
        "wall_s": round(time.time() - t0, 4),
        "late_executions": obs["engine"]["late_executions"],
        "fetch_stall_s": round(obs["engine"]["fetch_stall_seconds"], 6),
        "readahead_hits": hits,
        "readahead_misses": misses,
        "readahead_hit_rate": round(hits / max(hits + misses, 1), 4),
        "segment_sweeps": int(obs["store"]["segment_sweeps"]),
        "sweep_bytes_read": int(obs["store"]["sweep_bytes_read"]),
        "coalesced_windows": int(obs["store"]["coalesced_windows"]),
        "write_amplification": round(store.write_amplification, 4),
    }
    eng.close()
    return row


def prefetch_probe(events: int = 12_000, repeats: int = 3) -> Dict:
    """Fixed vs learned prefetch at equal memory on the log store under
    log-normal lateness: the learned backend's lateness-model-driven
    segment sweeps should serve the late re-reads from the read cache
    (high readahead hit rate) without making staleness worse. Each
    backend runs ``repeats`` times (interleaved) and the median fetch
    stall is the staleness proxy — single runs are noise-dominated at
    this scale. Reports per-backend median rows plus the headline
    ``readahead_hit_rate`` (learned) and the ``learned_vs_fixed``
    staleness ratio (<= 1 means the learned path is no worse)."""
    import tempfile
    from pathlib import Path

    root = Path(tempfile.mkdtemp(prefix="q4_prefetch_"))
    trials = {"fixed": [], "learned": []}
    for rep in range(repeats):
        for backend in ("fixed", "learned"):
            trials[backend].append(
                _prefetch_run(backend, events, root / f"{backend}{rep}"))

    def median_row(rows):
        rows = sorted(rows, key=lambda r: r["fetch_stall_s"])
        row = dict(rows[len(rows) // 2])
        row["fetch_stall_s"] = round(float(np.median(
            [r["fetch_stall_s"] for r in rows])), 6)
        return row

    fixed = median_row(trials["fixed"])
    learned = median_row(trials["learned"])
    return {
        "rows": [fixed, learned],
        "repeats": repeats,
        "readahead_hit_rate": learned["readahead_hit_rate"],
        # staleness proxy at equal memory: learned / fixed fetch stall
        "learned_vs_fixed": round(
            learned["fetch_stall_s"] / max(fixed["fetch_stall_s"], 1e-9),
            4),
    }


def _fault_run(rate: float, ladder: bool, events: int, root) -> Dict:
    import time

    from repro.configs.base import AionConfig
    from repro.core import StreamEngine, TumblingWindows
    from repro.core.cleanup import PredictiveCleanup
    from repro.core.events import EventBatch
    from repro.core.operators import make_operator
    from repro.core.triggers import DeltaTTrigger
    from repro.storage import make_store
    from repro.testing import FaultInjector, FaultyBlockStore

    aion = AionConfig(block_size=256, store_backend="log",
                      store_segment_bytes=64 << 10,
                      io_retry_backoff=0.0005,
                      breaker_error_threshold=2 if ladder else 0)
    store = None
    if rate > 0:
        inner = make_store("log", root, segment_bytes=64 << 10)
        inj = FaultInjector(seed=int(rate * 1000),
                            rates={op: rate for op in
                                   ("get", "put", "commit", "readahead")},
                            max_consecutive=2)
        store = FaultyBlockStore(inner, inj)
    eng = StreamEngine(
        assigner=TumblingWindows(10.0),
        operator=make_operator("average", aion.block_size, 1),
        aion=aion, value_width=1,
        # tiny memory tiers: the run is dominated by the (faulty)
        # storage path, so retries/shedding are load-bearing
        device_budget_bytes=1 << 17, host_budget_bytes=1 << 14,
        spill_dir=root,
        cleanup=PredictiveCleanup(initial_bound=80.0,
                                  min_history=1 << 62),
        trigger=DeltaTTrigger(executions=3),
        store=store,
    )
    rng = np.random.default_rng(13)
    now, emitted = 0.0, 0
    t0 = time.time()
    while emitted < events:
        n = min(500, events - emitted)
        delay = np.where(rng.random(n) < 0.5,
                         rng.uniform(0.0, 2.0, n),
                         rng.uniform(0.0, 30.0, n))
        ts = np.maximum(now - delay, 0.0)
        eng.ingest(
            EventBatch(rng.integers(0, 8, n), ts,
                       np.ones((n, 1), np.float32)), now)
        emitted += n
        eng.advance_watermark(max(now - 2.0, 0.0), now)
        eng.poll(now)
        now += rng.uniform(1.0, 3.0)
    eng.flush_deferred(now)
    for t in np.linspace(now, now + 80.0, 12):
        eng.poll(t)
    eng.io.drain()
    wall = time.time() - t0
    m = eng.metrics
    obs = eng.observability()
    row = {
        "fault_rate": rate,
        "ladder": ladder,
        "events": events,
        "wall_s": round(wall, 4),
        "events_per_s": round(events / max(wall, 1e-9), 1),
        "late_executions": obs["engine"]["late_executions"],
        "fetch_stall_s": round(obs["engine"]["fetch_stall_seconds"], 6),
        "io_retries": int(obs["io"]["retries"]),
        "io_gave_up": int(obs["io"]["gave_up"]),
        "injected_faults": (int(store.injector.stats["injected"])
                            if store is not None else 0),
        "readahead_shed": int(obs["io"]["readahead_shed"]),
        "shed_readahead_drives": obs["engine"]["shed_readahead_drives"],
        "shed_prefetch_rounds": obs["engine"]["shed_prefetch_rounds"],
        "demoted_sync_rounds": obs["engine"]["demoted_sync_rounds"],
        "deferred_events": obs["engine"]["deferred_events"],
        "ladder_transitions": len(m.ladder_transitions),
        "max_degradation_level": max(
            [lvl for _, lvl in m.ladder_transitions], default=0),
    }
    eng.close()
    return row


def fault_probe(events: int = 8_000,
                rates=(0.0, 0.02, 0.10)) -> Dict:
    """Self-healing I/O under injected store faults (ISSUE 9): each
    fault rate runs with the degradation ladder on and off (breaker
    disabled). Retries absorb every transient (``io_gave_up`` must stay
    0 — ``max_consecutive`` < retry limit); the ladder rows show
    speculative work being shed (readahead drives, prefetch rounds)
    while demand throughput survives. The headline compares throughput
    at the top fault rate with and without the ladder."""
    import tempfile
    from pathlib import Path

    root = Path(tempfile.mkdtemp(prefix="q4_faults_"))
    rows = []
    for rate in rates:
        for ladder in ((True,) if rate == 0 else (True, False)):
            rows.append(_fault_run(rate, ladder, events,
                                   root / f"r{rate}_l{int(ladder)}"))
    top = [r for r in rows if r["fault_rate"] == max(rates)]
    on = next(r for r in top if r["ladder"])
    off = next((r for r in top if not r["ladder"]), on)
    return {
        "rows": rows,
        "all_recovered": all(r["io_gave_up"] == 0 for r in rows),
        # >1 means the ladder bought throughput under faults
        "ladder_throughput_gain": round(
            on["events_per_s"] / max(off["events_per_s"], 1e-9), 4),
    }


def run() -> Dict[str, List[Dict]]:
    return {
        "staleness_vs_executions": staleness_vs_executions(),
        "executions_for_bounds": executions_for_bounds(),
    }


def main(emit_json: str = "BENCH_q4_staleness.json",
         prefetch_only: bool = False,
         faults_only: bool = False) -> Dict:
    partial = prefetch_only or faults_only
    if partial:
        # --prefetch / --faults: run just that probe and merge it into
        # the existing JSON (keeps the other sections from the last full
        # run instead of recomputing them)
        import os
        out = {}
        if emit_json and os.path.exists(emit_json):
            with open(emit_json) as f:
                out = json.load(f)
    else:
        out = run()
        out["store_probe"] = store_probe()
    if prefetch_only or not partial:
        out["prefetch_probe"] = prefetch_probe()
    if faults_only or not partial:
        out["fault_probe"] = fault_probe()
    if emit_json:
        with open(emit_json, "w") as f:
            json.dump(out, f, indent=2)
    return out


if __name__ == "__main__":
    import sys
    out = main(prefetch_only="--prefetch" in sys.argv[1:],
               faults_only="--faults" in sys.argv[1:])
    for section, rows in out.items():
        print(f"== {section}")
        if isinstance(rows, dict):
            print(rows)
        else:
            for r in rows:
                print(r)
