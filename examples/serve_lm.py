"""End-to-end serving driver: batched requests through the AION-tiered
paged KV cache and the Pallas paged-attention kernel.

A small device page pool forces cold sessions to offload host-side
(p-bucket) and restage (proactive caching) — the serving realization of
the paper's technique.

    PYTHONPATH=src python examples/serve_lm.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cleanup import PredictiveCleanup
from repro.serve.kvcache import TieredKVCache
from repro.serve.scheduler import ContinuousBatcher, Request

HKV, D, PAGE = 4, 64, 16


def main():
    rng = np.random.default_rng(0)
    cache = TieredKVCache(
        num_device_pages=24, page_size=PAGE, num_kv_heads=HKV, head_dim=D,
        num_layers=1, dtype=jnp.float32,
        cleanup=PredictiveCleanup(coverage=0.9, confidence=0.9,
                                  min_history=20, initial_bound=30.0))
    sched = ContinuousBatcher(cache, max_batch=4, pages_per_seq=16)

    # 8 requests with prompts of varying length
    n_req = 8
    for rid in range(n_req):
        plen = int(rng.integers(20, 60))
        req = Request(request_id=rid, session_id=rid, prompt_len=plen,
                      max_new_tokens=24, arrived_at=0.0)
        kp = rng.normal(size=(1, plen, HKV, D)).astype(np.float32)
        vp = rng.normal(size=(1, plen, HKV, D)).astype(np.float32)
        sched.submit(req, kp, vp, now=0.0)

    def q_fn(sids):
        return jnp.asarray(rng.normal(size=(len(sids), HKV * 2, D)),
                           jnp.float32)

    def kv_fn(sids):
        return (rng.normal(size=(len(sids), 1, HKV, D)).astype(np.float32),
                rng.normal(size=(len(sids), 1, HKV, D)).astype(np.float32))

    t0 = time.time()
    now, steps = 1.0, 0
    while len(sched.completed) < n_req and steps < 200:
        sched.step(q_fn, kv_fn, now=now)
        now += 0.05
        steps += 1
    dt = time.time() - t0

    tok = sum(r.generated for r in sched.completed)
    print(f"completed {len(sched.completed)}/{n_req} requests, "
          f"{tok} tokens in {dt:.2f}s ({tok / dt:.0f} tok/s)")
    print(f"tiering: {cache.stats['staged']} pages staged, "
          f"{cache.stats['destaged']} destaged, "
          f"{cache.stats['evicted_sessions']} sessions cleaned up; "
          f"device pages in use: {cache.device_pages_used()}"
          f"/{cache.num_device_pages}")


if __name__ == "__main__":
    main()
