"""The paper's three mechanisms, end to end, on one stream.

1. predictive cleanup — the engine learns the lateness distribution and
   tightens the purge bound from the conservative default;
2. staleness trigger — minimum re-executions to meet the staleness SLA,
   compared against the deltat/deltaev baselines (Fig. 9);
3. proactive caching — fetch-stall with and without pre-staging.

    PYTHONPATH=src python examples/late_event_stream.py
"""
import numpy as np

from repro.core.cleanup import PredictiveCleanup
from repro.core.staleness import (
    deltaev_times, deltat_times, executions_for_bound, max_staleness_of,
    minimize_max_staleness,
)
from repro.data.generators import lateness_delays

T = 100.0
rng = np.random.default_rng(0)


def cleanup_demo():
    print("== predictive cleanup: adaptive max-allowed-lateness bound")
    c = PredictiveCleanup(coverage=0.99, confidence=0.95,
                          initial_bound=3600.0, min_history=100)
    for n in (100, 1000, 20000):
        c.observe(lateness_delays("lnorm", n, T, rng))
        print(f"  after {c.hist.total:6d} observations: "
              f"bound = {c.current_bound():9.2f}s "
              f"(conservative start was 3600s)")


def trigger_demo():
    print("\n== staleness trigger vs deltat/deltaev (paper Fig. 9)")
    delays = lateness_delays("lnorm", 20000, T, rng)
    print(f"  {'K':>3s} {'aion':>9s} {'deltat':>9s} {'deltaev':>9s}")
    for k in (4, 8, 16):
        a = minimize_max_staleness(delays, T, k).max_staleness
        d = max_staleness_of(deltat_times(T, k), delays, T)
        e = max_staleness_of(deltaev_times(delays, T, k), delays, T)
        print(f"  {k:3d} {a:9.4f} {d:9.4f} {e:9.4f}")
    for bound in (0.1, 0.05, 0.01):
        ka = executions_for_bound(
            lambda k: minimize_max_staleness(delays, T, k).times,
            delays, T, bound)
        kt = executions_for_bound(lambda k: deltat_times(T, k), delays, T,
                                  bound)
        ke = executions_for_bound(lambda k: deltaev_times(delays, T, k),
                                  delays, T, bound)
        print(f"  bound {bound}: aion needs K={ka}, deltat K={kt}, "
              f"deltaev K={ke}")


def prestage_demo():
    print("\n== proactive caching: fetch stall with/without pre-staging")
    from benchmarks.q3_ablation import run_one
    for variant in ("aion-full", "no-pre-stgng"):
        r = run_one(variant)
        print(f"  {variant:14s} fetch_stall={r['fetch_stall_s']:.3f}s "
              f"late_execs={r['late_execs']}")


if __name__ == "__main__":
    cleanup_demo()
    trigger_demo()
    prestage_demo()
