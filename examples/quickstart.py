"""Quickstart: AION in ~60 lines.

An event-time stream with heavy lateness flows through a tumbling-window
average. Watch: (1) results are amended as late events arrive, (2) device
memory stays bounded because past-window state lives in the p-bucket,
(3) the staleness trigger schedules the minimum re-executions.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.configs.base import AionConfig
from repro.configs.workloads import AVERAGE
from repro.core import (
    PeriodicWatermarkGenerator, StreamEngine, TumblingWindows, WindowId,
)
from repro.core.operators import make_operator
from repro.data.generators import make_generator


def main():
    gen = make_generator(AVERAGE, seed=0)
    aion = AionConfig(block_size=512, max_staleness=0.05)
    engine = StreamEngine(
        assigner=TumblingWindows(AVERAGE.window_duration),
        operator=make_operator("average", aion.block_size, gen.width),
        aion=aion,
        value_width=gen.width,
        watermark_gen=PeriodicWatermarkGenerator(AVERAGE.window_duration),
        device_budget_bytes=64 << 20,          # the m-bucket tier budget
    )
    # teach the lateness estimator quickly (normally learned online)
    engine.cleanup.min_history = 50
    engine.cleanup.coverage = 0.9

    wd = AVERAGE.window_duration
    now = 4 * wd
    for step in range(12):
        batch = gen.batch(3000, now)           # lognormal lateness (paper)
        engine.ingest(batch, now)
        engine.advance_watermark(now, now)
        engine.poll(now)
        if step % 3 == 0:
            print(f"t={now:7.1f}s  windows={len(engine.windows):3d} "
                  f"device={engine.device_bytes() / 2**20:6.1f}MB "
                  f"host={engine.host_bytes() / 2**20:6.1f}MB "
                  f"late_events={engine.metrics.ingested_late}")
        now += wd

    # drive planned late re-executions to amend past results
    for t in np.linspace(now, now + engine.cleanup.current_bound(), 20):
        engine.poll(t)

    print(f"\nexecutions: live={engine.metrics.live_executions} "
          f"late={engine.metrics.late_executions} "
          f"purged={engine.metrics.purged_windows}")
    print(f"io: {engine.io.stats['staged_blocks']} staged / "
          f"{engine.io.stats['destaged_blocks']} destaged blocks, "
          f"{engine.io.stats['preemptions']} destage preemptions")
    some = sorted(engine.results)[:3]
    for wid in some:
        print(f"window [{wid.start:.0f},{wid.end:.0f}): "
              f"avg={engine.results[wid]:.2f}")
    engine.close()


if __name__ == "__main__":
    main()
