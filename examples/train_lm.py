"""End-to-end training driver: a small LM trained for a few hundred steps
with the production substrate (prefetch pipeline, async checkpoints,
restart manager) — the same code path launch/train.py uses on a pod.

    PYTHONPATH=src python examples/train_lm.py --steps 200

By default trains a ~10M-param starcoder2-family model on CPU (a 100M
model is a flag away: --dmodel 768 --layers 12 — sized for real hardware).
"""
import argparse
import dataclasses
import time
from pathlib import Path

import jax
import numpy as np

from repro.configs import ARCHS, reduced
from repro.data.generators import token_batches
from repro.data.pipeline import PrefetchPipeline
from repro.models import build_model
from repro.train import OptConfig, make_train_step
from repro.train.checkpoint import AsyncCheckpointer
from repro.train.train_step import init_train_state


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--dmodel", type=int, default=384)
    ap.add_argument("--layers", type=int, default=6)
    ap.add_argument("--ckpt-dir", type=Path,
                    default=Path("/tmp/repro_train_lm"))
    args = ap.parse_args()

    cfg = dataclasses.replace(
        reduced(ARCHS["starcoder2-7b"]),
        d_model=args.dmodel, num_layers=args.layers,
        d_ff=args.dmodel * 4, num_heads=max(args.dmodel // 64, 1),
        num_kv_heads=max(args.dmodel // 256, 1), vocab_size=8192,
    )
    model = build_model(cfg)
    print(f"training {cfg.param_count() / 1e6:.1f}M params, "
          f"{args.steps} steps of {args.batch}x{args.seq} tokens")

    state = init_train_state(model, jax.random.PRNGKey(0))
    step_fn = jax.jit(make_train_step(model, OptConfig(
        lr=1e-3, warmup_steps=20, total_steps=args.steps)))
    data = PrefetchPipeline(
        token_batches(cfg.vocab_size, args.batch, args.seq), depth=2)
    ckpt = AsyncCheckpointer(args.ckpt_dir, keep=2)

    t0 = time.time()
    losses = []
    for step in range(args.steps):
        state, metrics = step_fn(state, next(data))
        losses.append(float(metrics["loss"]))
        if (step + 1) % 20 == 0:
            rate = args.batch * args.seq * (step + 1) / (time.time() - t0)
            print(f"step {step + 1:4d} loss={losses[-1]:.4f} "
                  f"({rate:,.0f} tok/s)")
        if (step + 1) % 100 == 0:
            ckpt.save(state, step + 1)
    ckpt.wait()
    data.close()
    print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"(random = {np.log(cfg.vocab_size):.3f}) in "
          f"{time.time() - t0:.0f}s; checkpoints in {args.ckpt_dir}")


if __name__ == "__main__":
    main()
